"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs.  One test per assigned arch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import lm
from repro.optim import AdamWConfig, init_opt_state
from repro.train.step import build_train_step

pytestmark = pytest.mark.slow      # jax-heavy model smoke: nightly tier

B, S = 2, 32
KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module", params=sorted(ARCHS))
def arch_setup(request):
    cfg = get_config(request.param).reduced()
    params = lm.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend:
        batch["frontend"] = jnp.ones(
            (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    return cfg, params, batch


def test_forward_shapes_and_finite(arch_setup):
    cfg, params, batch = arch_setup
    logits, aux = jax.jit(
        lambda p, b: lm.forward(p, cfg, b["tokens"], b.get("frontend")))(
        params, batch)
    n_front = cfg.frontend_tokens if cfg.frontend else 0
    assert logits.shape == (B, S + n_front, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_train_step_reduces_loss(arch_setup):
    cfg, params, batch = arch_setup
    opt_cfg = AdamWConfig(lr=5e-3)
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(build_train_step(cfg, opt_cfg, lr=5e-3))
    p, o, m0 = step(params, opt, batch)
    for _ in range(4):
        p, o, m = step(p, o, batch)
    assert np.isfinite(float(m["loss"]))
    assert float(m["loss"]) < float(m0["loss"])   # memorizes a fixed batch


def test_decode_step(arch_setup):
    cfg, params, batch = arch_setup
    cache = lm.init_cache(cfg, B, 64)
    step = jax.jit(lambda p, c, t, pos: lm.decode_step(p, cfg, c, t, pos))
    logits, cache = step(params, cache, batch["tokens"][:, 0], jnp.int32(0))
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    logits2, _ = step(params, cache, batch["tokens"][:, 1], jnp.int32(1))
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_decode_matches_forward(arch_setup):
    """Greedy decode logits must match teacher-forced forward logits (the
    KV-cache/recurrent-state path is equivalent to the parallel path)."""
    cfg, params, batch = arch_setup
    toks = batch["tokens"][:, :8]
    if cfg.frontend:
        pytest.skip("frontend archs prepend embeddings in forward")
    logits_fwd, _ = lm.forward(params, cfg, toks)
    cache = lm.init_cache(cfg, B, 16)
    outs = []
    for i in range(8):
        lg, cache = lm.decode_step(params, cfg, cache, toks[:, i],
                                   jnp.int32(i))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32),
        np.asarray(logits_fwd, np.float32), rtol=0.15, atol=0.15)
