"""Fault tolerance: chaos-injected copy backends, retry/deadline
machinery, channel health, degraded-mode serving and the tier audit.

Unit tests pin each fault-path mechanism in isolation (retry backoff,
health transitions, bounded waits, pool teardown); end-to-end tests run
the scenario workloads under seeded fault profiles and assert the
acceptance invariants: chaos off is bitwise identical to the fault-free
pipeline, chaos on keeps >= 85% of fault-free steady slack with zero
audit violations, and no fault profile can deadlock a run.
"""

import concurrent.futures
import math

import pytest

from repro.core import (PAPER_DRAM_NVM, ChannelHealth, ChaosBackend,
                        CopyTimeoutError, FaultSpec, RuntimeConfig,
                        TransientCopyError, UnimemRuntime, calibrate,
                        make_backend)
from repro.core.data_objects import DataObject, ObjectRegistry
from repro.core.faults import DegradedServe, EvictionRollback
from repro.core.monitor import VariationMonitor
from repro.core.mover import (CpuPoolBackend, JaxTierBackend,
                              SlackAwareMover, _PoolCopy)
from repro.core.planner import MoveOp, ScheduledMove
from repro.core.policy import STAGE_NAMES, fault_provenance
from repro.sim import SimulationEngine
from repro.sim.workloads import (SCENARIO_WORKLOADS, chaos_gated_spec,
                                 chaos_heavy_spec)
from repro.sim.engine import SimObjectAccess, SimPhaseSpec
from repro.sim.workloads import SimWorkload

MB = 1024 ** 2
MACHINE = PAPER_DRAM_NVM.scaled(bw_scale=0.5, lat_scale=2.0)
CF = calibrate(MACHINE)


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------
def run_workload(wl: SimWorkload, fault_spec=None, iters: int = 8,
                 capacity: int = 256 * MB, **config_kw):
    rt = UnimemRuntime(
        MACHINE,
        RuntimeConfig(fast_capacity_bytes=capacity, mover="slack",
                      copy_channels=2, drift_threshold=10.0,
                      fault_spec=fault_spec, **config_kw),
        cf=CF)
    for n, s in wl.objects.items():
        rt.alloc(n, size_bytes=s, chunkable=wl.chunkable.get(n, False))
    rt.start_loop([p.name for p in wl.phases],
                  static_refs=wl.static_ref_counts())
    res = SimulationEngine(MACHINE, wl, runtime=rt).run(iters)
    return res, rt


def _mover_fixture(spec: FaultSpec, size_mb: int = 64):
    now = [0.0]
    reg = ObjectRegistry()
    reg.alloc("a", size_mb * MB)
    inner = make_backend("sim", MACHINE, now_fn=lambda: now[0],
                         mover="slack", channels=2)
    backend = ChaosBackend(inner, spec)
    mover = SlackAwareMover(reg, backend, retry_limit=3,
                            straggler_factor=4.0)
    return reg, backend, mover, now


def _entry(name: str, dst: str, size_bytes: int) -> ScheduledMove:
    return ScheduledMove(MoveOp(name, dst, 0, 0, size_bytes),
                         window_s=1.0, duration_s=0.5, slack_s=0.5)


# ---------------------------------------------------------------------------
# retry machinery
# ---------------------------------------------------------------------------
def test_transient_retry_succeeds():
    # seed 1: first rng draw 0.134 (< 0.5 -> injected failure), second
    # 0.847 (pass) — exactly one retry, then the copy issues
    reg, backend, mover, _ = _mover_fixture(
        FaultSpec(seed=1, transient_rate=0.5))
    e = _entry("a", "fast", reg["a"].size_bytes)
    h = mover._start_with_retry(e, reg["a"], None, 0.0)
    assert h is not None
    assert mover.stats.n_retries == 1
    assert mover.fault_events == []
    assert ("transient", "a", -1) in backend.fault_log


def test_transient_retries_exhaust_to_degraded_serve():
    reg, backend, mover, _ = _mover_fixture(
        FaultSpec(seed=0, transient_rate=1.0))
    e = _entry("a", "fast", reg["a"].size_bytes)
    h = mover._start_with_retry(e, reg["a"], None, 0.0)
    assert h is None
    [ev] = mover.fault_events
    assert isinstance(ev, DegradedServe)
    assert ev.obj == "a" and ev.reason == "retries_exhausted"
    assert mover.stats.n_degraded == 1
    # at most retry_limit re-attempts were ever made
    assert len(backend.fault_log) <= 1 + mover.retry_limit


def test_failed_eviction_rolls_back_residency():
    reg, backend, mover, _ = _mover_fixture(
        FaultSpec(seed=0, transient_rate=1.0))
    reg["a"].tier = "fast"
    e = _entry("a", "slow", reg["a"].size_bytes)
    h = mover._start_with_retry(e, reg["a"], None, 0.0)
    assert h is None
    assert reg["a"].tier == "fast"          # residency rolled back intact
    [ev] = mover.fault_events
    assert isinstance(ev, EvictionRollback)
    assert mover.stats.n_failed_evictions == 1


# ---------------------------------------------------------------------------
# channel health state machine
# ---------------------------------------------------------------------------
def test_channel_health_transitions_and_probation():
    health = ChannelHealth(quarantine_after=2, probation_interval=3)
    assert health.avoid() == set()
    health.record_fault(0)
    assert health.state(0) == "degraded" and health.avoid() == set()
    health.record_fault(0)
    assert health.state(0) == "quarantined"
    assert health.avoid() == {0}            # choose 1
    assert health.avoid() == {0}            # choose 2
    assert health.avoid() == set()          # choose 3: probation probe
    health.record_success(0)                # probe landed clean
    assert health.state(0) == "degraded"
    health.record_success(0)
    assert health.state(0) == "healthy"
    assert health.summary() == {}


def test_channel_health_ignores_unknown_channels():
    health = ChannelHealth()
    health.record_fault(-1)
    health.record_fault(None)
    health.record_success(-1)
    assert health.summary() == {} and health.avoid() == set()


# ---------------------------------------------------------------------------
# bounded-wait contract (all four backends)
# ---------------------------------------------------------------------------
def _sim_handle(kind: str):
    now = [0.0]
    reg = ObjectRegistry()
    reg.alloc("big", 256 * MB)
    backend = make_backend("sim", MACHINE, now_fn=lambda: now[0],
                           mover=("slack" if kind == "channel" else "fifo"),
                           channels=2)
    return backend, backend.start_move(reg["big"], "fast")


@pytest.mark.parametrize("kind", ["serial", "channel"])
def test_bounded_wait_sim_backends(kind):
    backend, h = _sim_handle(kind)
    stall = h.done                          # virtual stall from t=0
    assert stall > 0
    with pytest.raises(CopyTimeoutError):
        backend.wait(h, timeout=stall / 10)
    assert backend.wait(h, timeout=stall * 10) == pytest.approx(stall)
    assert backend.wait(h) == pytest.approx(stall)   # unbounded still fine


def test_bounded_wait_cpu_pool():
    backend = CpuPoolBackend(MACHINE)
    try:
        reg = ObjectRegistry()
        reg.alloc("x", MB, payload=None)
        stuck = _PoolCopy(reg["x"], "fast", concurrent.futures.Future())
        with pytest.raises(CopyTimeoutError):
            backend.wait(stuck, timeout=0.05)
        assert not backend.is_done(stuck)
    finally:
        backend.shutdown()


def test_bounded_wait_jax_leaves():
    class _NeverReady:
        def is_ready(self):
            return False

    class _Ready:
        def is_ready(self):
            return True

        def block_until_ready(self):
            return self

    with pytest.raises(CopyTimeoutError):
        JaxTierBackend._wait_leaves([_NeverReady()], 0.05, "test fence")
    JaxTierBackend._wait_leaves([_Ready()], 0.05, "test fence")
    JaxTierBackend._wait_leaves([_Ready()], None, "test fence")


# ---------------------------------------------------------------------------
# CpuPoolBackend teardown
# ---------------------------------------------------------------------------
def test_cpu_pool_shutdown_idempotent():
    backend = CpuPoolBackend(MACHINE)
    backend.shutdown()
    backend.shutdown()                      # double shutdown: no-op
    backend.__del__()                       # del-after-shutdown: no-op
    reg = ObjectRegistry()
    reg.alloc("x", MB, payload={"w": [1.0]})
    with pytest.raises(RuntimeError):
        backend.start_move(reg["x"], "fast")


def test_cpu_pool_del_without_shutdown():
    backend = CpuPoolBackend(MACHINE)
    backend.__del__()                       # releases the pool
    backend.__del__()                       # and stays reentrant


# ---------------------------------------------------------------------------
# chaos backend + registry
# ---------------------------------------------------------------------------
def test_chaos_registry_factory():
    backend = make_backend("chaos", MACHINE, chaos_inner="sim",
                           now_fn=lambda: 0.0, mover="slack", channels=2,
                           fault_spec=FaultSpec(seed=7, transient_rate=1.0))
    assert isinstance(backend, ChaosBackend)
    reg = ObjectRegistry()
    reg.alloc("a", MB)
    with pytest.raises(TransientCopyError):
        backend.start_move(reg["a"], "fast")
    with pytest.raises(ValueError):
        make_backend("chaos", MACHINE, chaos_inner="chaos")


def test_chaos_straggler_channel_stretches_service_time():
    spec = FaultSpec(straggler_channel=1, straggler_channel_factor=8.0)
    now = [0.0]
    reg = ObjectRegistry()
    reg.alloc("a", 64 * MB)
    reg.alloc("b", 64 * MB)
    backend = ChaosBackend(make_backend(
        "sim", MACHINE, now_fn=lambda: now[0], mover="slack", channels=2),
        spec)
    ha = backend.start_move(reg["a"], "fast")    # lands on channel 0
    hb = backend.start_move(reg["b"], "fast")    # lands on channel 1: 8x
    slow, fast = (ha, hb) if ha.channel == 1 else (hb, ha)
    assert (slow.done - slow.start) > 3 * (fast.done - fast.start)


def test_chaos_stuck_handle_wedges_until_cancelled():
    spec = FaultSpec(seed=0, stuck_rate=1.0)
    now = [0.0]
    reg = ObjectRegistry()
    reg.alloc("a", 64 * MB)
    inner = make_backend("sim", MACHINE, now_fn=lambda: now[0],
                         mover="slack", channels=2)
    backend = ChaosBackend(inner, spec)
    h = backend.start_move(reg["a"], "fast")
    assert not math.isfinite(h.done)
    assert not backend.is_done(h)
    with pytest.raises(CopyTimeoutError):
        backend.wait(h, timeout=1.0)
    assert inner.cancel(h)                  # cancel frees the channel
    assert math.isfinite(inner._free_at[h.channel])
    assert reg["a"].tier == "slow"          # the tier never flipped


# ---------------------------------------------------------------------------
# monitor: confirmed faults bypass the debounce
# ---------------------------------------------------------------------------
def test_monitor_faulted_observation_bypasses_debounce():
    clean, faulted = VariationMonitor(patience=3), VariationMonitor(patience=3)
    for m in (clean, faulted):
        m.set_baseline(0, 1.0)
    assert clean.observe(0, 2.0) is None            # strike 1 of 3
    assert faulted.observe(0, 2.0, faulted=True) is not None


# ---------------------------------------------------------------------------
# fault provenance
# ---------------------------------------------------------------------------
def test_fault_provenance_stage():
    sp = fault_provenance(2, 1, profile_epoch=3, chunk_generation=4)
    assert sp.stage == "fault" and sp.stage not in STAGE_NAMES
    assert "2 degraded serves" in sp.detail
    assert "1 eviction rollbacks" in sp.detail


# ---------------------------------------------------------------------------
# end to end: chaos off is bitwise identical, chaos on degrades gracefully
# ---------------------------------------------------------------------------
def test_zero_rate_chaos_is_bitwise_identical():
    wl_a = SCENARIO_WORKLOADS["kv_serving"]()
    wl_b = SCENARIO_WORKLOADS["kv_serving"]()
    base, _ = run_workload(wl_a)
    wrapped, rt = run_workload(wl_b, fault_spec=FaultSpec())
    assert isinstance(rt.backend, ChaosBackend)
    assert wrapped.iteration_times == base.iteration_times
    assert rt.backend.fault_log == []


def test_chaos_run_is_deterministic():
    spec = chaos_gated_spec(seed=42)
    runs = [run_workload(SCENARIO_WORKLOADS["kv_serving"](),
                         fault_spec=spec) for _ in range(2)]
    (res_a, rt_a), (res_b, rt_b) = runs
    assert res_a.iteration_times == res_b.iteration_times
    for key in ("n_retries", "n_degraded_serves", "n_eviction_rollbacks",
                "n_straggler_reissues", "n_audit_violations"):
        assert rt_a.stats()[key] == rt_b.stats()[key]
    assert rt_a.backend.fault_log == rt_b.backend.fault_log


def test_gated_chaos_keeps_slo_and_quarantines_straggler():
    wl = SCENARIO_WORKLOADS["kv_serving"]()
    base, _ = run_workload(SCENARIO_WORKLOADS["kv_serving"]())
    chaos, rt = run_workload(wl, fault_spec=chaos_gated_spec(seed=42))
    s = rt.stats()
    assert (base.steady_iteration_time / chaos.steady_iteration_time) >= 0.85
    assert s["n_audit_violations"] == 0
    assert rt.audit_tiers(heal=False).ok    # final state reconciles too
    assert s["n_retries"] > 0               # faults were actually injected
    # the 8x straggler channel was flagged; the healthy channel stayed so
    assert s["channel_health"].get(1) in ("degraded", "quarantined")
    assert 0 not in s["channel_health"]


def test_heavy_chaos_never_deadlocks_and_stays_consistent():
    wl = SCENARIO_WORKLOADS["moe_churn"]()
    res, rt = run_workload(wl, fault_spec=chaos_heavy_spec(seed=5))
    assert math.isfinite(res.total_time)
    kinds = {k for k, _, _ in rt.backend.fault_log}
    assert "stuck" in kinds                 # the profile did inject wedges
    s = rt.stats()
    assert s["n_degraded_serves"] > 0
    assert s["n_audit_violations"] == 0
    assert rt.audit_tiers(heal=False).ok
    for ev in rt.fault_log:                 # provenance is fully stamped
        assert ev.iteration >= 0 and ev.reason


# ---------------------------------------------------------------------------
# tier audit: detection + self-healing
# ---------------------------------------------------------------------------
def _divergence_workload() -> SimWorkload:
    phases = [
        SimPhaseSpec("p0", 0.01, {"hot": SimObjectAccess(2e6, 0.5)}),
        SimPhaseSpec("p1", 0.01, {"warm": SimObjectAccess(4e6, 1.0)}),
    ]
    return SimWorkload("diverge", phases,
                       {"hot": 64 * MB, "warm": 96 * MB, "cold": 64 * MB})


def test_audit_clean_on_fault_free_run():
    _, rt = run_workload(_divergence_workload(), capacity=128 * MB)
    audit = rt.audit_tiers()
    assert audit.ok and not audit.healed
    assert rt.stats()["n_audits"] >= 1


def test_audit_detects_divergence_and_heals():
    _, rt = run_workload(_divergence_workload(), capacity=128 * MB)
    # simulate a residency leak the plan knows nothing about: an
    # unreferenced object materializes in the fast tier
    rt.registry["cold"].tier = "fast"
    audit = rt.audit_tiers()
    assert not audit.ok
    assert any("cold" in v for v in audit.violations)
    assert audit.healed and audit.clean_after_heal
    # the heal booked a corrective eviction; once drained the registry
    # reconciles to the plan
    rt.mover.drain()
    assert rt.registry["cold"].tier == "slow"
    assert rt.audit_tiers(heal=False).ok


def test_audit_without_heal_reports_only():
    _, rt = run_workload(_divergence_workload(), capacity=128 * MB)
    rt.registry["cold"].tier = "fast"
    audit = rt.audit_tiers(heal=False)
    assert not audit.ok and not audit.healed
    assert rt.registry["cold"].tier == "fast"   # untouched


# ---------------------------------------------------------------------------
# exception safety: a crashed iteration leaves the runtime serviceable
# ---------------------------------------------------------------------------
def test_exception_mid_iteration_with_outstanding_copies():
    wl = SCENARIO_WORKLOADS["kv_serving"]()
    _, rt = run_workload(wl, iters=3)
    with pytest.raises(RuntimeError, match="boom"):
        with rt.iteration():
            with rt.phase(wl.phases[0].name):
                pass                        # triggers/fences async moves
            raise RuntimeError("boom")      # outstanding copies in flight
    audit = rt.audit_tiers()
    assert audit.ok or (audit.healed and audit.clean_after_heal)
    # the next iteration is fully serviceable
    with rt.iteration():
        for ph in wl.phases:
            with rt.phase(ph.name):
                pass
    assert rt.audit_tiers().ok
