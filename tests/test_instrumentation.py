"""Instrumentation sources: XLA cost-analysis attribution end to end.

Acceptance (ISSUE 3): ``XlaCostAnalysisSource`` must produce *non-uniform*
``access_bins`` from a dry-run cell (a lowered/compiled XLA program) that
flow through the hot-chunk pipeline — profiler multinomial resampling,
skew-aware partitioning, histogram-mass chunk attribution, knapsack
placement — exactly like the simulator's density stream does.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (PAPER_DRAM_NVM, PhaseSample, RuntimeConfig, Session,
                        XlaCostAnalysisSource, calibrate)
from repro.core.partition import chunk_spans

MACHINE = PAPER_DRAM_NVM.scaled(bw_scale=0.5)
CF = calibrate(MACHINE)
KB = 1024

#: a "table" of 8 equal leaves; leaf 0 is read by several ops per step, the
#: tail leaves once each — the hot-head shape the pipeline must discover
N_LEAVES = 8
LEAF_SHAPE = (64, 1024)                      # 256 KiB per leaf (f32)
LEAF_BYTES = 64 * 1024 * 4


def _table_specs():
    return {f"l{i:02d}": jax.ShapeDtypeStruct(LEAF_SHAPE, jnp.float32)
            for i in range(N_LEAVES)}


def _step_fn(table, x):
    """Leaf l00 feeds four separate ops; every other leaf one op."""
    acc = table["l00"] @ x
    acc = acc + table["l00"].sum()
    acc = acc * table["l00"].mean()
    out = acc + table["l00"][0, 0]
    for i in range(1, N_LEAVES):
        out = out + (table[f"l{i:02d}"] @ x)
    return out.sum()


def _lowered():
    specs = _table_specs()
    x = jax.ShapeDtypeStruct((1024, 4), jnp.float32)
    return jax.jit(_step_fn).lower(specs, x), specs, x


# ---------------------------------------------------------------------------
def test_mlir_attribution_is_non_uniform():
    lowered, specs, _ = _lowered()
    sess = Session(MACHINE)
    obj = sess.register("table", specs, chunkable=True)
    src = XlaCostAnalysisSource(sess, n_bins=64)
    sample = src.bind("step", lowered, ["table", 1])
    assert sample.accesses["table"] > 0
    bins = np.asarray(sample.access_bins["table"])
    assert bins.shape == (64,)
    w = bins / bins.sum()
    # leaf 0 covers bins [0, 8); its extra fan-out must concentrate mass
    head = w[: 64 // N_LEAVES].sum()
    assert head > 2.0 / N_LEAVES            # >2x the uniform share
    tail = w[64 // N_LEAVES:]
    assert head > tail.max() * 2
    # accesses follow the operand footprint: 4 + 7 leaf reads
    expected = (4 + (N_LEAVES - 1)) * LEAF_BYTES / MACHINE.cacheline_bytes
    assert sample.accesses["table"] == pytest.approx(expected, rel=0.01)
    assert obj.leaf_spans is not None and len(obj.leaf_spans) == N_LEAVES


def test_compiled_hlo_attribution_parses():
    """The compiled-HLO text parser also attributes (fusion may merge uses,
    so only structure is asserted, not exact fan-out)."""
    lowered, specs, _ = _lowered()
    compiled = lowered.compile()
    sess = Session(MACHINE)
    sess.register("table", specs, chunkable=True)
    src = XlaCostAnalysisSource(sess, n_bins=64)
    sample = src.bind("step", compiled, ["table", 1])
    assert sample.accesses.get("table", 0) > 0
    assert sample.access_bins and "table" in sample.access_bins


def test_mlir_private_helper_funcs_not_charged_to_entry_params():
    """lax.scan lowers to a private func.func that re-declares %argN; its
    uses must not inflate the entry parameters' footprints (regression)."""
    def f(p, x):
        def body(c, _):
            return c @ p["w"], ()
        out, _ = jax.lax.scan(body, x, None, length=3)
        return out.sum() + p["b"].sum()
    specs = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32),
             "b": jax.ShapeDtypeStruct((8,), jnp.float32)}
    lowered = jax.jit(f).lower(specs, jax.ShapeDtypeStruct((8, 8),
                                                           jnp.float32))
    assert "func.func private" in lowered.as_text()   # the hazard exists
    sess = Session(MACHINE)
    sess.register("p", specs)
    src = XlaCostAnalysisSource(sess, n_bins=8)
    s = src.bind("step", lowered, ["p", 1])
    # each leaf is read exactly once in @main: (256 + 32) bytes / cacheline
    expected = (8 * 8 * 4 + 8 * 4) / MACHINE.cacheline_bytes
    assert s.accesses["p"] == pytest.approx(expected)


def test_hlo_param_counting_requires_both_boundaries():
    """`param_0` must not match inside `fused_param_0` (HLO names can be
    printed without the % sigil)."""
    from repro.core.instrumentation import _hlo_param_uses
    text = """ENTRY %main {
  param_0 = f32[8]{0} parameter(0)
  param_1 = f32[8]{0} parameter(1)
  fused_param_0 = f32[8]{0} add(param_1, param_1)
  out = f32[8]{0} add(param_0, fused_param_0)
}
"""
    uses = _hlo_param_uses(text)
    assert uses[0] == 1                  # only the true use, not the suffix
    assert uses[1] == 2


def test_sim_source_rejects_duplicate_phase_names():
    """Name-keyed phases: a workload with two phases of one name would
    silently collapse onto the last spec's physics — must raise."""
    from repro.core.data_objects import ObjectRegistry
    from repro.sim import SimObjectAccess, SimPhaseSpec, SimSource, SimWorkload
    wl = SimWorkload("dup", [
        SimPhaseSpec("compute", 0.01, {"a": SimObjectAccess(accesses=100.0)}),
        SimPhaseSpec("io", 0.01, {"a": SimObjectAccess(accesses=10.0)}),
        SimPhaseSpec("compute", 0.01, {"a": SimObjectAccess(accesses=50.0)}),
    ], {"a": 1024})
    with pytest.raises(ValueError, match="compute"):
        SimSource(MACHINE, wl, ObjectRegistry())


def test_unbound_phase_collects_empty_sample():
    sess = Session(MACHINE)
    src = XlaCostAnalysisSource(sess)
    s = src.collect("never_bound")
    assert isinstance(s, PhaseSample) and s.accesses == {}


# ---------------------------------------------------------------------------
def test_xla_bins_flow_through_hotchunk_pipeline_end_to_end():
    """Acceptance: the dry-run attribution drives the full pipeline — the
    profiler resamples the XLA histogram, skew-aware bisection cuts the
    table along it, and the planner keeps the hot head fast-resident while
    the cold tail stays evictable."""
    lowered, specs, _ = _lowered()
    cap = 1 * 1024 * KB                      # 1 MiB: the 2 MiB table can't fit
    rt = Session(MACHINE, RuntimeConfig(fast_capacity_bytes=cap,
                                        mover="fifo", backend="jax"),
                 cf=CF)
    rt.register("table", specs, chunkable=True)
    src = XlaCostAnalysisSource(rt, n_bins=64)
    # elapsed such that the table's footprint is bandwidth-class
    # (accessed bytes / phase time well above T1 * slow-tier peak)
    src.bind("step", lowered, ["table", 1], elapsed=5e-4)
    rt.attach_source(src)

    for _ in range(3):
        with rt.iteration():
            with rt.phase("step"):
                pass

    assert rt.plan is not None
    # the profiler's measured histogram is non-uniform (resampled XLA bins)
    bins = rt.profiler.object_bins("table")
    assert bins, "no per-chunk attribution reached the profiler"
    w = next(iter(bins.values())).weights
    assert w.max() > 2.0 * w.mean()
    # the table was partitioned along the measured density
    spans = chunk_spans(rt.registry, "table")
    assert len(spans) > 1
    # the hot head (leaf 0's span) is fast-resident; the whole table is not
    size = sum(c.size_bytes for c, _, _ in spans)
    hot_chunks = [c for c, lo, hi in spans if lo < size // N_LEAVES]
    assert hot_chunks and all(c.tier == "fast" for c in hot_chunks)
    assert any(c.tier == "slow" for c, _, _ in spans)
    # and the final plan keeps the hot head resident in its phase
    residents = rt.plan.residents[0]
    assert any(c.name in residents for c in hot_chunks)


def test_leaf_edge_attribution_is_exact_per_leaf_histogram():
    """edges="leaf" (ISSUE 5): the source emits a variable-width
    multi-resolution Histogram with one bin per registered leaf span —
    exact per-leaf attribution, no grid quantization."""
    from repro.core import Histogram

    lowered, specs, _ = _lowered()
    sess = Session(MACHINE)
    sess.register("table", specs, chunkable=True)
    src = XlaCostAnalysisSource(sess, edges="leaf")
    sample = src.bind("step", lowered, ["table", 1])
    h = sample.access_bins["table"]
    assert isinstance(h, Histogram)
    assert h.n_bins == N_LEAVES                # one bin per leaf
    w = h.weights
    # leaf 0's 4x fan-out lands exactly in its own bin: 4 of 11 reads
    assert w[0] == pytest.approx(4.0 / (4 + (N_LEAVES - 1)), rel=1e-6)
    assert np.allclose(w[1:], 1.0 / (4 + (N_LEAVES - 1)), rtol=1e-6)
    # the histogram drives the profiler like any other truth stream
    rt = Session(MACHINE, RuntimeConfig(fast_capacity_bytes=768 * KB,
                                        backend="sim"))
    rt.register("table", specs, chunkable=True)
    src2 = XlaCostAnalysisSource(rt, edges="leaf")
    src2.bind("step", lowered, ["table", 1], elapsed=5e-4)
    rt.attach_source(src2)
    for _ in range(2):
        with rt.iteration():
            with rt.phase("step"):
                pass
    bins = rt.profiler.object_bins("table")
    assert bins
    hw = next(iter(bins.values())).weights
    assert hw[0] > 2.0 * hw[1:].mean()


def test_leaf_edge_mode_validated():
    sess = Session(MACHINE)
    with pytest.raises(ValueError, match="uniform"):
        XlaCostAnalysisSource(sess, edges="nope")
