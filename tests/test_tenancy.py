"""Multi-tenant serving layer: namespaces, QoS shares, admission control,
channel ownership, the bounded fault log, and continuous calibration.

Unit tests pin the pure share/apportionment math and the namespace rules;
end-to-end tests run ``tenant_serving`` under the ``bandwidth_partition``
policy and assert the acceptance invariants: per-tenant shares conserve
the physical capacity and channels exactly, per-phase per-tenant fast
residency never exceeds a tenant's share, the cold tenant is admission-
demoted with ``DegradedServe`` provenance, and — the other direction —
declaring no tenants (or tenants under the default policy) leaves the
PR 7 pipeline bit-identical (golden digest).
"""

import hashlib
import json
import random

import pytest

from repro.core import (PAPER_DRAM_NVM, FaultLog, FaultSpec, RuntimeConfig,
                        TenantSpec, UnimemRuntime, apportion, calibrate,
                        capacity_shares, channel_shares, tenant_of)

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                                 # seeded fallback shim
    from _propcheck import st, given, settings
from repro.core.data_objects import ObjectRegistry
from repro.core.faults import DegradedServe
from repro.core.mover import ChannelSimBackend
from repro.core.tenancy import (admission_control, per_tenant_p99, qualify,
                                split_by_tenant)
from repro.sim import SimulationEngine
from repro.sim.workloads import (TENANT_SERVING_QOS, kv_serving,
                                 tenant_serving)

MB = 1024 ** 2
MACHINE = PAPER_DRAM_NVM.scaled(bw_scale=0.5, lat_scale=2.0)
CF = calibrate(MACHINE)

# the PR 7 pipeline's kv_serving plan (256 MB, drift pinned, 8 iters),
# captured before the tenancy layer landed: every default-config run —
# with or without declared-but-idle tenants, and under the zero-tenant
# bandwidth_partition fallback — must reproduce it bit-identically
PR7_GOLDEN = ("62b4841234212db2", 1.0603286323200083)


def _plan_digest(plan):
    d = dict(strategy=plan.strategy,
             residents=[sorted(r) for r in plan.residents],
             moves=[(m.obj, m.dst, m.trigger_phase, m.needed_by, m.size_bytes,
                     m.est_unhidden_cost, m.est_benefit) for m in plan.moves],
             predicted=plan.predicted_iteration_time,
             baseline=plan.baseline_iteration_time,
             schedule=[(s.op.obj, s.window_s, s.duration_s, s.slack_s)
                       for s in plan.schedule])
    return hashlib.sha256(json.dumps(d, sort_keys=True).encode()) \
        .hexdigest()[:16]


def run_plain(wl, iters=8, capacity=256 * MB, tenants=(), fault_spec=None,
              **config_kw):
    rt = UnimemRuntime(MACHINE,
                       RuntimeConfig(fast_capacity_bytes=capacity,
                                     drift_threshold=10.0,
                                     fault_spec=fault_spec, **config_kw),
                       cf=CF)
    for t, (p, s) in tenants:
        rt.tenant(t, priority=p, slo=s)
    statics = wl.static_ref_counts()
    for n, s in wl.objects.items():
        rt.register(n, s, chunkable=wl.chunkable.get(n, False),
                    static_refs=statics.get(n))
    res = SimulationEngine(MACHINE, wl, runtime=rt).run(iters)
    return res, rt


def run_tenanted(iters=12, capacity=192 * MB, qos=None, **config_kw):
    qos = qos if qos is not None else TENANT_SERVING_QOS
    wl = tenant_serving()
    rt = UnimemRuntime(MACHINE,
                       RuntimeConfig(fast_capacity_bytes=capacity,
                                     copy_channels=7, drift_threshold=10.0,
                                     **config_kw),
                       cf=CF)
    handles = {t: rt.tenant(t, priority=p, slo=s)
               for t, (p, s) in qos.items()}
    statics = wl.static_ref_counts()
    for n, s in wl.objects.items():
        t, _, rest = n.partition("/")
        handles[t].register(rest, s, static_refs=statics.get(n))
    res = SimulationEngine(MACHINE, wl, runtime=rt).run(iters)
    return res, rt, wl


# ---------------------------------------------------------------------------
# namespaces
# ---------------------------------------------------------------------------
def test_tenant_spec_validation():
    with pytest.raises(ValueError):
        TenantSpec("")
    with pytest.raises(ValueError):
        TenantSpec("a/b")
    with pytest.raises(ValueError):
        TenantSpec("a#b")
    with pytest.raises(ValueError):
        TenantSpec("a", priority=0.0)
    with pytest.raises(ValueError):
        TenantSpec("a", slo=-1.0)
    assert TenantSpec("a", priority=3.0, slo=0.5).weight == 6.0


def test_tenant_of_strips_chunk_suffix_and_checks_registry():
    assert tenant_of("a/kv") == "a"
    assert tenant_of("a/kv#3") == "a"
    assert tenant_of("plain") is None
    assert tenant_of("plain#2") is None
    specs = {"a": TenantSpec("a")}
    assert tenant_of("a/kv#1", specs) == "a"
    assert tenant_of("b/kv", specs) is None     # undeclared prefix: unowned
    assert qualify("a", "kv") == "a/kv"


def test_namespace_collision_rules():
    rt = UnimemRuntime(MACHINE, RuntimeConfig(fast_capacity_bytes=64 * MB),
                       cf=CF)
    a, b = rt.tenant("a"), rt.tenant("b")
    a.register("kv", 4 * MB)
    b.register("kv", 4 * MB)                    # cross-tenant collision: ok
    assert {o.name for o in rt.registry} == {"a/kv", "b/kv"}
    with pytest.raises(ValueError):
        a.register("kv", 4 * MB)                # same-tenant duplicate
    # redeclaring a tenant: same contract returns a handle, a different
    # contract is a hard error
    assert rt.tenant("a").name == "a"
    with pytest.raises(ValueError):
        rt.tenant("a", priority=2.0)


def test_split_by_tenant():
    specs = {"a": TenantSpec("a"), "b": TenantSpec("b")}
    owned, rest = split_by_tenant(["a/x", "a/y#2", "b/x", "w", "c/x"], specs)
    assert owned == {"a": ["a/x", "a/y#2"], "b": ["b/x"]}
    assert rest == ["w", "c/x"]


# ---------------------------------------------------------------------------
# share math
# ---------------------------------------------------------------------------
def test_capacity_shares_exact_conservation_and_demand_cap():
    rng = random.Random(7)
    for trial in range(50):
        n = rng.randint(1, 6)
        tenants = {f"t{i}": TenantSpec(f"t{i}",
                                       priority=rng.uniform(0.1, 8.0),
                                       slo=rng.uniform(0.25, 2.0))
                   for i in range(n)}
        demand = {t: rng.randint(0, 300) * MB for t in tenants}
        cap = rng.randint(1, 400) * MB
        shares = capacity_shares(cap, tenants, demand)
        assert sum(shares.values()) == min(cap, sum(demand.values()))
        for t in tenants:
            assert 0 <= shares[t] <= demand[t]


def test_capacity_shares_monotone_in_priority():
    demand = {"a": 100 * MB, "b": 100 * MB, "c": 100 * MB}
    prev = -1
    for prio in (0.5, 1.0, 2.0, 4.0, 8.0):
        tenants = {"a": TenantSpec("a", priority=prio),
                   "b": TenantSpec("b"), "c": TenantSpec("c")}
        got = capacity_shares(120 * MB, tenants, demand)["a"]
        assert got >= prev
        prev = got


def test_capacity_shares_work_conserving():
    # a sated tenant's surplus flows to the hungry one
    tenants = {"big": TenantSpec("big", priority=4.0),
               "small": TenantSpec("small")}
    shares = capacity_shares(100 * MB, tenants,
                             {"big": 10 * MB, "small": 500 * MB})
    assert shares["big"] == 10 * MB
    assert shares["small"] == 90 * MB


def test_channel_shares_partition_exactly():
    rng = random.Random(11)
    for trial in range(50):
        n = rng.randint(1, 5)
        tenants = {f"t{i}": TenantSpec(f"t{i}",
                                       priority=rng.uniform(0.1, 8.0))
                   for i in range(n)}
        n_ch = rng.randint(1, 9)
        out = channel_shares(n_ch, tenants)
        flat = sorted(c for chs in out.values() for c in chs)
        assert flat == list(range(n_ch))


@settings(max_examples=80, deadline=None)
@given(total=st.integers(0, 500),
       weights=st.lists(st.floats(0.0, 50.0), min_size=1, max_size=8),
       caps=st.lists(st.integers(0, 120), min_size=8, max_size=8))
def test_apportion_conserves_total(total, weights, caps):
    """The shared largest-remainder helper's conservation law: integer
    allotments sum exactly to the total (capped: to min(total, sum of
    caps)), each within one unit of its real-valued quota and never
    above its cap — for capacity splits, channel counts and the
    coordinator's link-pair shares alike."""
    wsum = sum(weights) or 1.0
    quotas = {f"k{i}": total * w / wsum for i, w in enumerate(weights)}
    out = apportion(total, quotas)
    assert sum(out.values()) == total
    for k, q in quotas.items():
        assert int(q) <= out[k] <= int(q) + 1
    capped = {f"k{i}": c for i, c in zip(range(len(weights)), caps)}
    out = apportion(total, quotas, caps=capped)
    assert sum(out.values()) == min(total, sum(capped.values()))
    for k in quotas:
        assert 0 <= out[k] <= capped[k]


def test_admission_control_cold_and_churn():
    tenants = {"hot": TenantSpec("hot"), "cold": TenantSpec("cold"),
               "thrash": TenantSpec("thrash")}
    traffic = {"hot": 1e9, "cold": 1e3, "thrash": 8e8}
    footprint = {"hot": 100 * MB, "cold": 100 * MB, "thrash": 100 * MB}
    out = admission_control(tenants, traffic, footprint, 64 * MB,
                            heat_floor=0.1)
    assert set(out) == {"cold"} and out["cold"].startswith("cold:")
    out = admission_control(
        tenants, traffic, footprint, 64 * MB, heat_floor=0.1,
        churn_guard=2.0,
        hot_bytes={"hot": 10 * MB, "thrash": 400 * MB})
    assert set(out) == {"cold", "thrash"}
    assert out["thrash"].startswith("over-quota:")
    # both knobs off: nobody is demoted
    assert admission_control(tenants, traffic, footprint, 64 * MB) == {}


def test_per_tenant_p99_sums_tenant_phases():
    class Ev:
        def __init__(self, it, idx, stall, dur):
            self.iteration, self.phase_index = it, idx
            self.stall_s, self.duration_s = stall, dur

    names = ["a/p0", "b/p0", "a/p1", "loose"]
    trace = []
    for it in range(4):
        trace += [Ev(it, 0, 0.0, 1.0 + it), Ev(it, 1, 0.5, 2.0),
                  Ev(it, 2, 0.0, 10.0), Ev(it, 3, 0.0, 99.0)]
    p = per_tenant_p99(trace, names, {"a": None, "b": None}, steady_frac=0.5)
    assert p["a"] == 1.0 + 3 + 10.0        # worst steady iteration, both phases
    assert p["b"] == 2.5
    assert "loose" not in p


# ---------------------------------------------------------------------------
# bounded fault log
# ---------------------------------------------------------------------------
def test_fault_log_ring_semantics():
    log = FaultLog(limit=3)
    for i in range(5):
        log.append(i)
    assert list(log) == [2, 3, 4]
    assert len(log) == 3 and log.dropped == 2 and bool(log)
    assert log[0] == 2 and log[-1] == 4 and log[1:] == [3, 4]
    log.clear()
    assert len(log) == 0 and not log and log.dropped == 0
    unbounded = FaultLog(limit=0)
    for i in range(10):
        unbounded.append(i)
    assert len(unbounded) == 10 and unbounded.dropped == 0


def test_fault_log_bound_keeps_counts_exact():
    wl = kv_serving()
    spec = FaultSpec(seed=3, transient_rate=0.3, late_fail_rate=0.1)
    free, rt_free = run_plain(wl, fault_spec=spec, fault_log_limit=0)
    total = len(rt_free.fault_log)
    assert total > 4
    capped, rt_cap = run_plain(wl, fault_spec=spec, fault_log_limit=4)
    assert len(rt_cap.fault_log) == 4
    assert rt_cap.fault_log.dropped == total - 4
    assert rt_cap.stats()["fault_log_dropped"] == total - 4
    # the ring keeps the *newest* entries and drops nothing from the stats
    assert [repr(e) for e in rt_cap.fault_log] == \
        [repr(e) for e in list(rt_free.fault_log)[-4:]]
    for k in ("n_retries", "n_degraded_serves", "n_eviction_rollbacks"):
        assert capped.stats[k] == free.stats[k]


# ---------------------------------------------------------------------------
# channel ownership at the backend
# ---------------------------------------------------------------------------
def _backend_fixture(channels=3):
    now = [0.0]
    reg = ObjectRegistry()
    objs = [reg.alloc(f"o{i}", 8 * MB, tier="slow") for i in range(6)]
    be = ChannelSimBackend(MACHINE, lambda: now[0], channels=channels)
    return be, objs, now


def test_prefer_routes_to_owned_idle_channel():
    be, objs, _ = _backend_fixture()
    assert be.start_move(objs[0], "fast").channel == 0      # earliest-free
    assert be.start_move(objs[1], "fast",
                         prefer=frozenset({2})).channel == 2


def test_prefer_borrows_idle_foreign_channel_when_owned_busy():
    be, objs, _ = _backend_fixture()
    be.start_move(objs[0], "fast", prefer=frozenset({2}))   # ch 2 busy
    h = be.start_move(objs[1], "fast", prefer=frozenset({2}))
    assert h.channel == 0        # lowest-numbered idle channel, borrowed
    be.start_move(objs[2], "fast", prefer=frozenset({1}))   # ch 1 busy too
    h2 = be.start_move(objs[3], "fast", prefer=frozenset({2}))
    assert h2.channel == 2       # nothing idle: queue on the owned channel


def test_prefer_none_is_earliest_free_chooser():
    be_a, objs_a, _ = _backend_fixture()
    be_b, objs_b, _ = _backend_fixture()
    seq_a = [be_a.start_move(o, "fast").channel for o in objs_a]
    seq_b = [be_b.start_move(o, "fast", prefer=None).channel for o in objs_b]
    assert seq_a == seq_b


# ---------------------------------------------------------------------------
# end-to-end: bandwidth partition on tenant_serving
# ---------------------------------------------------------------------------
def test_partition_conserves_capacity_and_channels():
    res, rt, wl = run_tenanted(policy="bandwidth_partition")
    plan = rt.plan
    assert plan.strategy == "bandwidth_partition"
    shares = dict(plan.tenant_shares)
    channels = dict(plan.tenant_channels)
    demoted = set(plan.tenant_admission)
    assert demoted == {"cold"}
    # shares conserve the fast tier exactly (admitted demand exceeds it)
    assert sum(shares.values()) == 192 * MB
    assert shares["whale"] > shares["m0"] > 0
    # channels partition range(copy_channels) across admitted tenants
    flat = sorted(c for chs in channels.values() for c in chs)
    assert flat == list(range(7))
    assert len(channels["whale"]) == 4
    # per-phase, per-tenant *settled* fast residency never exceeds the
    # share.  Rotating objects legitimately overshoot between their fetch
    # and their scheduled departure (the tier audit uses the same
    # accounting), so only bytes with no booked eviction count.
    sizes = {o.name: o.size_bytes for o in rt.registry}
    departing = {m.obj for m in plan.moves if m.dst == "slow"}
    for residents in plan.residents:
        by_t = {}
        for name in residents:
            t = tenant_of(name, TENANT_SERVING_QOS)
            assert t is not None            # every object here is owned
            if name not in departing:
                by_t[t] = by_t.get(t, 0) + sizes[name]
        for t, used in by_t.items():
            assert used <= shares.get(t, 0)
    # and the mover received the ownership map
    assert rt.mover.channel_prefs == {
        t: frozenset(chs) for t, chs in channels.items()}


def test_admission_demotes_cold_tenant_with_provenance():
    res, rt, wl = run_tenanted(policy="bandwidth_partition")
    assert rt.stats()["n_admission_demotions"] >= 1
    evs = [e for e in rt.fault_log
           if isinstance(e, DegradedServe)
           and str(e.reason).startswith("admission:")]
    assert evs and all(e.obj == "cold" and e.tenant == "cold" for e in evs)
    assert "cold: density" in evs[0].reason
    # the demoted tenant's state is never fast-resident
    for residents in rt.plan.residents:
        assert "cold/archive" not in residents
    # declared QoS is visible in stats
    assert rt.stats()["n_tenants"] == 5


def test_namespace_isolation_of_attribution():
    res, rt, wl = run_tenanted(policy="bandwidth_partition")
    # every phase's profiled objects belong to the phase's own tenant:
    # attribution never bleeds across namespaces
    for idx, name in enumerate(p.name for p in wl.phases):
        t = tenant_of(name, TENANT_SERVING_QOS)
        for o in rt.registry:
            prof = rt.profiler.profile(idx, o.name)
            if prof is not None and prof.data_access > 0:
                assert tenant_of(o.name, TENANT_SERVING_QOS) == t


def test_partition_beats_aggregate_on_tail_p99():
    # the acceptance inequality the nightly gate enforces on the committed
    # row, reproduced at test scale (fewer iterations)
    uni, _, wl = run_tenanted(policy="unimem", iters=12)
    part, prt, _ = run_tenanted(policy="bandwidth_partition", iters=12)
    names = [p.name for p in wl.phases]
    p_uni = per_tenant_p99(uni.phase_trace, names, TENANT_SERVING_QOS)
    p_bp = per_tenant_p99(part.phase_trace, names, TENANT_SERVING_QOS)
    demoted = set(prt.plan.tenant_admission)
    tail = [t for t in TENANT_SERVING_QOS
            if t != "whale" and t not in demoted]
    assert tail
    tail_gain = min(p_uni[t] / p_bp[t] for t in tail)
    whale_ratio = p_uni["whale"] / p_bp["whale"]
    assert tail_gain >= 1.15
    assert whale_ratio >= 0.95


# ---------------------------------------------------------------------------
# default-config bit-identity (the PR 7 pipeline must be untouched)
# ---------------------------------------------------------------------------
def test_no_tenants_matches_pr7_golden():
    res, rt = run_plain(kv_serving())
    assert (_plan_digest(rt.plan), res.steady_iteration_time) == PR7_GOLDEN


def test_idle_tenants_under_default_policy_are_a_planning_noop():
    res, rt = run_plain(kv_serving(), tenants=[("svc", (2.0, 0.5))])
    assert rt.stats()["n_tenants"] == 1
    assert (_plan_digest(rt.plan), res.steady_iteration_time) == PR7_GOLDEN


def test_zero_tenant_bandwidth_partition_falls_back_bit_identically():
    res, rt = run_plain(kv_serving(), policy="bandwidth_partition")
    assert (_plan_digest(rt.plan), res.steady_iteration_time) == PR7_GOLDEN


def test_calibrate_every_off_and_feedback_off_are_noops():
    # calibrate_every without calibrate_feedback must not perturb anything
    res, rt = run_plain(kv_serving(), calibrate_every=3)
    assert (_plan_digest(rt.plan), res.steady_iteration_time) == PR7_GOLDEN


# ---------------------------------------------------------------------------
# continuous calibration
# ---------------------------------------------------------------------------
def test_calibrate_every_rearms_measurements():
    def drive(**kw):
        calls = []

        class Counting(UnimemRuntime):
            def _on_baseline_measured(self, measured):
                calls.append(self._iteration)
                return super()._on_baseline_measured(measured)

        wl = kv_serving()
        rt = Counting(MACHINE,
                      RuntimeConfig(fast_capacity_bytes=256 * MB,
                                    drift_threshold=10.0,
                                    calibrate_feedback=True, **kw),
                      cf=CF)
        statics = wl.static_ref_counts()
        for n, s in wl.objects.items():
            rt.register(n, s, static_refs=statics.get(n))
        SimulationEngine(MACHINE, wl, runtime=rt).run(16)
        return calls

    epoch_only = drive()
    periodic = drive(calibrate_every=2)
    # the periodic re-arm keeps measuring long after the plan epoch closed
    assert len(periodic) > len(epoch_only)
    assert max(periodic) > max(epoch_only)


def test_fold_note_carries_tenant_provenance():
    res, rt, wl = run_tenanted(policy="bandwidth_partition", iters=8)
    # phases 0/1 are whale/decode0 and m0/decode0
    rt._iter_phase_elapsed = {0: 0.1, 1: 0.2}
    note = rt._fold_note()
    assert note == f"iter{rt._iteration}[m0,whale]"
    # without tenants the note is the bare iteration stamp
    res2, rt2 = run_plain(kv_serving(), iters=2)
    rt2._iter_phase_elapsed = {0: 0.1}
    assert rt2._fold_note() == f"iter{rt2._iteration}"
