"""Hot-chunk placement pipeline: per-chunk attribution conservation,
skew-aware partitioning, vectorized-planner equivalence, and the
incremental-replan regression (plan never dropped once built)."""

import math
import random

import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # no hypothesis: seeded shim
    from _propcheck import st, given, settings

from repro.core import (CalibrationConstants, PAPER_DRAM_NVM, PhaseProfiler,
                        Planner, RuntimeConfig, UnimemRuntime,
                        build_phase_graph, calibrate)
from repro.core.data_objects import DataObject, ObjectRegistry
from repro.core.partition import (auto_partition, bin_mass, chunk_spans,
                                  partition_object_spans, resplit_refs,
                                  skew_boundaries)
from repro.core.phase import PhaseTraceEvent
from repro.core.profiler import ObjectPhaseProfile
from repro.sim import (SKEWED_SCENARIO_WORKLOADS, SimulationEngine,
                       power_law_density)

MB = 1024 ** 2
M = PAPER_DRAM_NVM.scaled(bw_scale=0.5, lat_scale=2.0)


# ---------------------------------------------------------------------------
# profiler: running mean, accessed_bytes, bin sampling
# ---------------------------------------------------------------------------
def test_observe_running_mean_not_clobber():
    """profile_iterations > 1 must average observations, not last-write-win."""
    prof = PhaseProfiler(M, seed=0, noise=0.0)
    for t in (0.1, 0.3):
        prof.observe(PhaseTraceEvent(0, t, {"a": 1e6}))
    p = prof.profile(0, "a")
    assert p.weight == pytest.approx(2.0)
    assert p.phase_time == pytest.approx(0.2)           # mean of 0.1, 0.3
    assert p.data_access == pytest.approx(1e6)          # no noise -> exact
    assert prof.phase_time(0) == pytest.approx(0.2)


def test_observe_noise_shrinks_with_iterations():
    """Averaging N noisy observations lands closer to the true count than a
    single observation does (the point of multi-iteration profiling)."""
    errs = []
    for n_obs in (1, 16):
        prof = PhaseProfiler(M, seed=3, noise=0.05)
        for _ in range(n_obs):
            prof.observe(PhaseTraceEvent(0, 0.1, {"a": 1e6}))
        errs.append(abs(prof.profile(0, "a").data_access - 1e6))
    assert errs[1] < errs[0]


def test_accessed_bytes_implemented():
    p = ObjectPhaseProfile(0, "o", data_access=1e6, n_samples=1e5,
                           samples_with_access=1e4, phase_time=0.1)
    assert p.accessed_bytes == pytest.approx(1e6 * 64.0)
    prof = PhaseProfiler(M, seed=0)
    prof.observe(PhaseTraceEvent(0, 0.1, {"a": 1e6}))
    q = prof.profile(0, "a")
    assert q.accessed_bytes == pytest.approx(
        q.data_access * M.cacheline_bytes)


def test_bin_sampling_tracks_true_density():
    truth = np.array(power_law_density(16, 1.5))
    truth /= truth.sum()
    prof = PhaseProfiler(M, seed=1)
    for _ in range(8):
        prof.observe(PhaseTraceEvent(0, 0.5, {"a": 1e6},
                                     access_bins={"a": list(truth)}))
    h = prof.profile(0, "a").bin_weights
    assert h is not None and len(h) == 16
    assert np.abs(h.weights - truth).max() < 0.03    # sampled, but close

    # decay keeps the estimate but lets fresh observations dominate
    prof.decay(0.1)
    flat = [1.0] * 16
    for _ in range(8):
        prof.observe(PhaseTraceEvent(0, 0.5, {"a": 1e6},
                                     access_bins={"a": flat}))
    h2 = prof.profile(0, "a").bin_weights
    assert np.abs(h2.weights - 1.0 / 16).max() < 0.05


# ---------------------------------------------------------------------------
# conservation: per-chunk attribution sums to the parent's true count
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 200))
@settings(max_examples=25, deadline=None)
def test_chunk_attribution_conserves_parent_refs(seed):
    rng = random.Random(seed)
    reg = ObjectRegistry()
    size = rng.randint(100, 400) * MB
    reg.alloc("big", size, chunkable=True)
    n_bins = rng.choice([8, 16, 64])
    weights = [rng.random() ** 2 for _ in range(n_bins)]
    total_refs = rng.uniform(1e5, 1e7)
    graph = build_phase_graph([("p0", {"big": total_refs})], times=[0.1])
    prof = PhaseProfiler(M, seed=seed)
    prof.observe(PhaseTraceEvent(0, 0.1, {"big": total_refs},
                                 access_bins={"big": weights}))
    prof.annotate_graph(graph)
    observed_total = graph[0].refs["big"]
    cap = rng.randint(30, 90) * MB
    auto_partition(reg, graph, cap, profiler=prof)
    chunks = [o for o in reg if o.parent == "big"]
    assert len(chunks) >= 2
    assert sum(c.size_bytes for c in chunks) == size
    # per-chunk attributed accesses sum to the parent's (observed) count
    attributed = sum(graph[0].refs.get(c.name, 0.0) for c in chunks)
    assert attributed == pytest.approx(observed_total, rel=1e-9)


def test_bin_mass_is_a_measure():
    w = power_law_density(64, 1.3)
    assert bin_mass(w, 0.0, 1.0) == pytest.approx(1.0)
    cuts = [0.0, 0.13, 0.5, 0.77, 1.0]
    parts = [bin_mass(w, a, b) for a, b in zip(cuts, cuts[1:])]
    assert sum(parts) == pytest.approx(1.0)
    assert all(p >= 0 for p in parts)


# ---------------------------------------------------------------------------
# skew-aware partitioning picks the hot head
# ---------------------------------------------------------------------------
def test_skew_boundaries_refine_hot_region():
    """Under a power-law histogram the hot head is cut into finer chunks
    than the cold tail, and the head chunks capture most of the mass."""
    size = 512 * MB
    w = power_law_density(64, 1.5)        # head-heavy, unpermuted
    bounds = skew_boundaries(size, [w], coarse_bytes=64 * MB,
                             min_chunk_bytes=4 * MB)
    sizes = [b - a for a, b in zip([0] + bounds, bounds)]
    assert bounds[-1] == size
    assert min(sizes) < 16 * MB           # fine chunks somewhere
    assert sizes[0] <= sizes[-1]          # head at least as fine as tail
    # the first quarter of the byte range carries most of the mass and got
    # more cuts than the last quarter
    head_cuts = sum(1 for b in bounds if b <= size // 4)
    tail_cuts = sum(1 for b in bounds if b > 3 * size // 4)
    assert head_cuts > tail_cuts


def test_uniform_histogram_recovers_equal_chunking():
    """A measured histogram with no skew degenerates to an equal split:
    every chunk the same size and none above the conservative
    capacity/chunk_divisor ceiling (the paper's policy as the uniform
    limit; bisection lands on 40 MB instead of 64 MB chunks)."""
    size = 320 * MB
    bounds = skew_boundaries(size, [[1.0] * 64], coarse_bytes=64 * MB,
                             min_chunk_bytes=4 * MB)
    sizes = {b - a for a, b in zip([0] + bounds, bounds)}
    assert len(sizes) == 1                  # equal chunks
    assert max(sizes) <= 64 * MB            # conservative ceiling holds


@given(seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_skew_partition_places_hot_head(seed):
    """Property: after skew-aware partitioning of a power-law object, the
    chunks covering the hottest measured bins end up with higher per-byte
    reference density than the coldest ones."""
    rng = random.Random(seed)
    alpha = rng.uniform(1.1, 1.8)
    size = rng.randint(300, 600) * MB
    reg = ObjectRegistry()
    reg.alloc("adj", size, chunkable=True)
    w = power_law_density(64, alpha)       # hot head at byte 0
    graph = build_phase_graph([("gather", {"adj": 1e7})], times=[0.1])
    prof = PhaseProfiler(M, seed=seed)
    for _ in range(4):
        prof.observe(PhaseTraceEvent(0, 0.1, {"adj": 1e7},
                                     access_bins={"adj": w}))
    prof.annotate_graph(graph)
    auto_partition(reg, graph, 256 * MB, profiler=prof)
    spans = chunk_spans(reg, "adj")
    assert len(spans) >= 2
    dens = [(graph[0].refs.get(c.name, 0.0) / c.size_bytes, lo)
            for c, lo, hi in spans]
    head_density = dens[0][0]
    tail_density = dens[-1][0]
    assert head_density > 2 * tail_density


# ---------------------------------------------------------------------------
# planner: vectorized path is plan-identical to the scalar path
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 300))
@settings(max_examples=30, deadline=None)
def test_vectorized_planner_matches_legacy(seed):
    rng = random.Random(seed)
    reg = ObjectRegistry()
    n_obj = rng.randint(1, 10)
    for i in range(n_obj):
        reg.alloc(f"o{i}", rng.randint(1, 120) * MB,
                  tier="fast" if rng.random() < 0.3 else "slow")
    if rng.random() < 0.6:               # a partitioned parent
        for k in range(rng.randint(2, 6)):
            reg.register(DataObject(
                name=f"big#{k}", size_bytes=rng.randint(10, 40) * MB,
                parent="big", chunk_index=k))
    n_ph = rng.randint(1, 6)
    refs, times = [], []
    has_chunks = any(o.parent == "big" for o in reg)
    for _ in range(n_ph):
        r = {o: rng.uniform(1e4, 1e6) for o in reg.names()
             if rng.random() < 0.5}
        if has_chunks and rng.random() < 0.5:
            r["big"] = rng.uniform(1e5, 1e6)    # parent-level profile
        refs.append(r)
        times.append(rng.uniform(0.01, 0.2))
    graph = build_phase_graph([(f"p{i}", rr) for i, rr in enumerate(refs)],
                              times=times)
    prof = PhaseProfiler(M, seed=seed)
    for i, rr in enumerate(refs):
        bins = ({"big": power_law_density(16, 1.4)}
                if "big" in rr and rng.random() < 0.5 else None)
        prof.observe(PhaseTraceEvent(i, times[i], dict(rr),
                                     access_bins=bins))
    prof.annotate_graph(graph)
    cap = rng.randint(50, 250) * MB
    vec = Planner(M, reg, CalibrationConstants(), cap, vectorized=True)
    leg = Planner(M, reg, CalibrationConstants(), cap, vectorized=False)
    for fn in ("plan_local", "plan_global"):
        a, b = getattr(vec, fn)(graph, prof), getattr(leg, fn)(graph, prof)
        assert a.moves == b.moves
        assert a.residents == b.residents
        assert a.predicted_iteration_time == b.predicted_iteration_time


# ---------------------------------------------------------------------------
# incremental replanning: the plan is never dropped once built
# ---------------------------------------------------------------------------
def _drive_replan(incremental: bool):
    rt = UnimemRuntime(
        M, RuntimeConfig(fast_capacity_bytes=20 * MB, mover="fifo",
                         incremental_replan=incremental,
                         enable_initial_placement=False),
        cf=calibrate(M))
    rt.alloc("a", size_bytes=10 * MB)
    rt.alloc("b", size_bytes=10 * MB)
    rt.alloc("c", size_bytes=15 * MB)
    rt.start_loop(["p0", "p1"])
    served_unplanned = 0
    ever_planned = False

    def run_iter(times, accs):
        nonlocal served_unplanned, ever_planned
        rt.begin_iteration()
        for i, t in enumerate(times):
            rt.phase_begin(i)
            if ever_planned and rt.plan is None:
                served_unplanned += 1
            rt.phase_end(i, elapsed=t, accesses=accs[i])
        rt.end_iteration()
        if rt.plan is not None:
            ever_planned = True

    hot_then = [{"a": 1e6, "b": 5e5}, {"a": 8e5}]   # a hot everywhere
    hot_now = [{"c": 1e6, "b": 2e5}, {"c": 9e5}]    # c takes over, a cold
    for _ in range(4):
        run_iter([0.1, 0.08], hot_then)
    for _ in range(8):
        run_iter([0.25, 0.08], hot_now)     # >10% drift on phase 0
    return rt, served_unplanned


def test_monitor_drifted_phases_diagnostic():
    from repro.core import VariationMonitor
    mon = VariationMonitor(threshold=0.1, patience=1)
    mon.set_baseline(0, 1.0)
    mon.set_baseline(1, 1.0)
    assert mon.observe(0, 1.5) is not None
    assert mon.drifted_phases() == [0]
    assert [e.phase_index for e in mon.consume_events()] == [0]
    assert mon.drifted_phases() == []       # consumed -> no stale re-trigger


def test_incremental_replan_never_serves_unplanned():
    """Acceptance: once a first plan exists, a drift-triggered replan must
    never serve an iteration with plan=None (regression on the
    variation-monitor path)."""
    rt, served_unplanned = _drive_replan(incremental=True)
    assert rt.n_replans >= 1
    assert rt.n_incremental_replans >= 1
    assert served_unplanned == 0
    assert rt.plan is not None
    stats = rt.stats()
    assert stats["n_replans"] == rt.n_replans


def test_legacy_full_reset_serves_unplanned():
    """The paper's full reset (the behaviour the incremental path replaces)
    drops the plan and serves unplaced iterations while re-profiling."""
    rt, served_unplanned = _drive_replan(incremental=False)
    assert rt.n_replans >= 1
    assert rt.n_incremental_replans == 0
    assert served_unplanned > 0


def test_incremental_replan_adapts_placement():
    """After drift shifts the hot object, the replanned placement follows:
    the newly-hot object ends up fast-resident."""
    rt, _ = _drive_replan(incremental=True)
    assert rt.plan is not None
    final_residents = rt.plan.residents[-1]
    assert "c" in final_residents or rt.registry["c"].tier == "fast"


# ---------------------------------------------------------------------------
# end to end: the hot-chunk pipeline beats uniform attribution on skew
# ---------------------------------------------------------------------------
def _run_pipeline(wl, chunk_aware: bool, iters: int = 8):
    rt = UnimemRuntime(
        M, RuntimeConfig(fast_capacity_bytes=256 * MB, mover="slack",
                         drift_threshold=10.0, chunk_aware=chunk_aware),
        cf=calibrate(M))
    for n, s in wl.objects.items():
        rt.alloc(n, size_bytes=s, chunkable=wl.chunkable.get(n, False))
    rt.start_loop([p.name for p in wl.phases],
                  static_refs=wl.static_ref_counts())
    return SimulationEngine(M, wl, runtime=rt).run(iters), rt


@pytest.mark.parametrize("wl_name", sorted(SKEWED_SCENARIO_WORKLOADS))
def test_hotchunk_beats_uniform_on_skew(wl_name):
    """Acceptance: per-chunk attribution + skew-aware partitioning strictly
    improves steady-state iteration time over PR 1's uniform-attribution
    slack engine on the skewed scenario variants."""
    wl = SKEWED_SCENARIO_WORKLOADS[wl_name]()
    uni, _ = _run_pipeline(wl, chunk_aware=False)
    hot, hrt = _run_pipeline(wl, chunk_aware=True)
    assert hot.steady_iteration_time < uni.steady_iteration_time
    # and it did so by actually discovering chunks
    assert any(o.parent is not None for o in hrt.registry)
