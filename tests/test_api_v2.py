"""Runtime API v2: old-vs-new parity, session-context properties, backend
registry, and the registration/re-entry bug fixes.

The compatibility shims on ``UnimemRuntime`` must be *exactly* the old API:
a driver hand-rolling the Table-2 choreography (alloc / start_loop /
begin_iteration / phase_begin / phase_end / end_iteration) and a v2 driver
(register / ``with rt.iteration()`` / ``with rt.phase(name)`` with the
simulator's SimSource) must produce bit-identical placement plans and
identical steady-state virtual-time numbers on the committed scenario
matrix.
"""

import warnings

import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # no hypothesis: seeded shim
    from _propcheck import st, given, settings

from repro.core import (PAPER_DRAM_NVM, AsyncJaxTierBackend,
                        ChannelSimBackend, CpuPoolBackend, JaxTierBackend,
                        ManualSource, RuntimeConfig, Session, SimTierBackend,
                        UnimemRuntime, available_backends, calibrate,
                        make_backend, register_backend)
from repro.core.data_objects import ObjectRegistry
from repro.sim import (NPB_WORKLOADS, SCENARIO_WORKLOADS,
                       SKEWED_SCENARIO_WORKLOADS, SimSource,
                       SimulationEngine)

MB = 1024 ** 2
MACHINE = PAPER_DRAM_NVM.scaled(bw_scale=0.5, lat_scale=2.0)
CF = calibrate(MACHINE)

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

#: parity coverage: one per scenario family + an NPB trace with chunking
PARITY_WORKLOADS = {
    "kv_serving": SCENARIO_WORKLOADS["kv_serving"],
    "moe_churn": SCENARIO_WORKLOADS["moe_churn"],
    "graph_chase": SCENARIO_WORKLOADS["graph_chase"],
    "graph_chase_skew": SKEWED_SCENARIO_WORKLOADS["graph_chase_skew"],
    "paged_serving": SKEWED_SCENARIO_WORKLOADS["paged_serving"],
    "cg": NPB_WORKLOADS["cg"],
}


def _config(mover: str = "slack") -> RuntimeConfig:
    return RuntimeConfig(fast_capacity_bytes=256 * MB, mover=mover,
                         drift_threshold=10.0)


def run_new_style(wl, *, iters: int = 8, mover: str = "slack"):
    """v2 driver: register + engine-driven iteration()/phase() contexts."""
    rt = UnimemRuntime(MACHINE, _config(mover), cf=CF)
    statics = wl.static_ref_counts()
    for n, s in wl.objects.items():
        rt.register(n, s, chunkable=wl.chunkable.get(n, False),
                    static_refs=statics.get(n))
    res = SimulationEngine(MACHINE, wl, runtime=rt).run(iters)
    return rt, res.iteration_times


def run_old_style(wl, *, iters: int = 8, mover: str = "slack"):
    """Pre-v2 driver: the Table-2 imperative choreography, hand-rolled the
    way sim/engine.py drove it before the session API existed."""
    cfg = _config(mover)
    rt = UnimemRuntime(MACHINE, cfg, cf=CF)
    for n, s in wl.objects.items():
        rt.alloc(n, size_bytes=s, chunkable=wl.chunkable.get(n, False))
    rt.start_loop([p.name for p in wl.phases],
                  static_refs=wl.static_ref_counts())
    clock = {"t": 0.0}
    backend = make_backend("sim", MACHINE, now_fn=lambda: clock["t"],
                           mover=cfg.mover, channels=cfg.copy_channels)
    rt.backend = backend
    rt.mover.backend = backend
    src = SimSource(MACHINE, wl, rt.registry)
    iter_times = []
    for _ in range(iters):
        rt.begin_iteration()
        t_iter = 0.0
        for i, ph in enumerate(wl.phases):
            stall = rt.phase_begin(i)
            s = src.collect(ph.name)
            clock["t"] += stall + s.elapsed
            t_iter += stall + s.elapsed
            rt.phase_end(i, elapsed=s.elapsed, accesses=s.accesses,
                         time_shares=s.time_shares,
                         access_bins=s.access_bins)
        rt.end_iteration()
        iter_times.append(t_iter)
    return rt, iter_times


@pytest.mark.parametrize("wl_name", sorted(PARITY_WORKLOADS))
def test_old_and_new_drivers_bit_identical(wl_name):
    """Acceptance: bit-identical plans and identical steady-state numbers
    from the deprecated imperative driver and the v2 session driver."""
    old_rt, old_times = run_old_style(PARITY_WORKLOADS[wl_name]())
    new_rt, new_times = run_new_style(PARITY_WORKLOADS[wl_name]())
    assert old_rt.plan is not None and new_rt.plan is not None
    assert old_rt.plan.moves == new_rt.plan.moves
    assert old_rt.plan.residents == new_rt.plan.residents
    assert (old_rt.plan.predicted_iteration_time
            == new_rt.plan.predicted_iteration_time)
    assert old_rt.plan.strategy == new_rt.plan.strategy
    assert old_times == new_times           # every virtual-time iteration
    # same final tier state, object by object (incl. discovered chunks)
    assert {o.name: o.tier for o in old_rt.registry} \
        == {o.name: o.tier for o in new_rt.registry}


def test_fifo_mover_parity():
    old_rt, old_times = run_old_style(PARITY_WORKLOADS["kv_serving"](),
                                      mover="fifo")
    new_rt, new_times = run_new_style(PARITY_WORKLOADS["kv_serving"](),
                                      mover="fifo")
    assert old_rt.plan.moves == new_rt.plan.moves
    assert old_times == new_times


def test_manual_source_matches_explicit_kwargs():
    """A ManualSource-fed session profiles identically to explicit
    per-phase keyword instrumentation."""
    def drive(use_source: bool):
        rt = Session(MACHINE, RuntimeConfig(fast_capacity_bytes=20 * MB,
                                            mover="fifo"), cf=CF)
        for n in ("a", "b"):
            rt.register(n, 12 * MB)
        acc = {"p0": {"a": 1e6}, "p1": {"b": 8e5}}
        if use_source:
            src = ManualSource()
            src.set("p0", accesses=acc["p0"], elapsed=0.1)
            src.set("p1", accesses=acc["p1"], elapsed=0.05)
            rt.attach_source(src)
        for _ in range(3):
            with rt.iteration():
                if use_source:
                    with rt.phase("p0"):
                        pass
                    with rt.phase("p1"):
                        pass
                else:
                    with rt.phase("p0", accesses=acc["p0"], elapsed=0.1):
                        pass
                    with rt.phase("p1", accesses=acc["p1"], elapsed=0.05):
                        pass
        return rt
    a, b = drive(True), drive(False)
    assert a.plan is not None
    assert a.plan.moves == b.plan.moves
    assert a.plan.predicted_iteration_time == b.plan.predicted_iteration_time


# ---------------------------------------------------------------------------
# session-context properties
# ---------------------------------------------------------------------------
def _session(cap_mb: int = 64) -> Session:
    return Session(MACHINE, RuntimeConfig(fast_capacity_bytes=cap_mb * MB,
                                          mover="fifo"), cf=CF)


def test_phase_auto_registers_on_first_use():
    rt = _session()
    rt.register("x", 8 * MB)
    with rt.iteration():
        with rt.phase("fwd", accesses={"x": 1e5}, elapsed=0.01):
            pass
        with rt.phase("bwd", accesses={"x": 2e5}, elapsed=0.02):
            pass
    assert rt.phase_names() == ["fwd", "bwd"]
    assert rt.plan is not None          # plan built after one iteration


@given(fail_phase=st.integers(0, 2), n_phases=st.integers(1, 3))
@settings(max_examples=20, deadline=None)
def test_phase_context_exception_safe(fail_phase, n_phases):
    """An exception inside a phase can never leave it open: the session
    accepts new phases afterwards and the crashed phase recorded nothing."""
    fail_phase = fail_phase % n_phases
    rt = _session()
    rt.register("x", 8 * MB)
    with pytest.raises(ValueError, match="boom"):
        with rt.iteration():
            for i in range(n_phases):
                with rt.phase(f"p{i}", accesses={"x": 1e5}, elapsed=0.01):
                    if i == fail_phase:
                        raise ValueError("boom")
    assert rt._open_phase is None
    assert rt._iter_open is False
    assert rt._events_this_iter == []   # abandoned iteration left no events
    # the session is reusable: a clean iteration still profiles and plans
    with rt.iteration():
        with rt.phase("p0", accesses={"x": 1e5}, elapsed=0.01):
            pass
    assert rt.plan is not None


def test_conditional_phase_after_plan_keeps_move_wrapping():
    """A phase auto-registered *after* the plan was built (a conditional
    eval/ckpt phase) must not change the modulus the plan's moves wrap
    with (regression: live n_phases re-wrapped trigger_phase=-1 moves
    onto the new phase, silently rerouting steady-state movement)."""
    def run(with_eval: bool):
        rt = _session(cap_mb=12)
        rt.register("hot", 10 * MB)
        rt.register("other", 10 * MB)
        moves_after_iter = []
        for step in range(8):
            with rt.iteration():
                with rt.phase("a", accesses={"hot": 1e6}, elapsed=0.1):
                    pass
                with rt.phase("b", accesses={"other": 8e5}, elapsed=0.1):
                    pass
                if with_eval and step >= 3:     # first seen mid-loop
                    with rt.phase("eval", accesses={"hot": 1e3},
                                  elapsed=0.1):
                        pass
            moves_after_iter.append(rt.mover.stats.n_moves)
        return rt, moves_after_iter

    base_rt, base_moves = run(False)
    eval_rt, eval_moves = run(True)
    assert base_rt.plan is not None
    # the hazard exists: the plan carries a previous-iteration trigger
    assert any(m.trigger_phase < 0 for m in base_rt.plan.moves)
    assert eval_rt._plan_n_phases == 2          # frozen at plan time
    assert eval_rt.phase_names() == ["a", "b", "eval"]
    # the conditional phase must not perturb the plan's movement schedule
    assert eval_moves == base_moves
    rt = _session()
    rt.register("x", 8 * MB)
    with rt.iteration():
        with rt.phase("outer", elapsed=0.01):
            with pytest.raises(RuntimeError, match="nest"):
                with rt.phase("inner", elapsed=0.01):
                    pass


def test_iteration_nesting_rejected():
    rt = _session()
    with rt.iteration():
        with pytest.raises(RuntimeError, match="nest"):
            with rt.iteration():
                pass


def test_phase_outside_iteration_rejected():
    rt = _session()
    with pytest.raises(RuntimeError, match="iteration"):
        with rt.phase("p0"):
            pass


def test_crashed_phase_not_folded_into_profile():
    rt = _session()
    rt.register("x", 8 * MB)
    try:
        with rt.iteration():
            with rt.phase("p0", accesses={"x": 1e9}, elapsed=123.0):
                raise RuntimeError("crash")
    except RuntimeError:
        pass
    assert rt.profiler.profile(0, "x") is None


# ---------------------------------------------------------------------------
# pytree-native registration + duplicate-name fix
# ---------------------------------------------------------------------------
def test_register_pytree_records_leaf_spans():
    import jax.numpy as jnp
    tree = {"w": jnp.ones((4, 8), jnp.float32),
            "b": jnp.ones((8,), jnp.float32)}
    rt = _session()
    obj = rt.register("layer", tree, manage_payload=False)
    assert obj.size_bytes == 4 * 8 * 4 + 8 * 4
    assert obj.payload is None          # manage_payload=False: sizes only
    spans = obj.leaf_spans
    assert len(spans) == 2
    offs = sorted((off, nb) for _, off, nb in spans)
    assert offs[0][0] == 0 and offs[0][1] + offs[1][1] == obj.size_bytes


def test_register_concrete_pytree_keeps_payload():
    import jax.numpy as jnp
    rt = _session()
    obj = rt.register("arr", jnp.ones((16,), jnp.float32))
    assert obj.payload is not None


def test_register_shape_structs_have_no_payload():
    import jax
    rt = _session()
    obj = rt.register("spec", {"a": jax.ShapeDtypeStruct((8, 8), "float32")})
    assert obj.payload is None
    assert obj.size_bytes == 8 * 8 * 4


def test_duplicate_register_raises_value_error():
    rt = UnimemRuntime(MACHINE, RuntimeConfig(fast_capacity_bytes=64 * MB),
                       cf=CF)
    rt.register("obj_a", 8 * MB)
    with pytest.raises(ValueError, match="obj_a"):
        rt.register("obj_a", 4 * MB)
    with pytest.raises(ValueError, match="obj_a"):
        rt.alloc("obj_a", size_bytes=4 * MB)   # deprecated shim, same check


def test_register_parent_of_live_chunks_raises():
    """Re-registering a name whose object was partitioned must fail loudly:
    a silent overwrite would orphan the live chunk state."""
    from repro.core.partition import partition_object
    reg = ObjectRegistry()
    reg.alloc("big", 100 * MB, chunkable=True)
    partition_object(reg, "big", 30 * MB)       # removes big, adds big#k
    assert "big" not in reg
    with pytest.raises(ValueError, match="big"):
        reg.alloc("big", 100 * MB)


# ---------------------------------------------------------------------------
# start_loop re-entry regression
# ---------------------------------------------------------------------------
def _drive_loop(rt, times, accs, iters=4):
    for _ in range(iters):
        rt.begin_iteration()
        for i, t in enumerate(times):
            rt.phase_begin(i)
            rt.phase_end(i, elapsed=t, accesses=accs[i])
        rt.end_iteration()


def test_start_loop_reentry_resets_plan_and_baselines():
    """A second start_loop on one runtime must not inherit the first loop's
    plan, monitor baselines, or accumulated profiles (regression for the
    re-entry bug: only _iteration/_profiling/graph/mover were reset)."""
    rt = UnimemRuntime(MACHINE,
                       RuntimeConfig(fast_capacity_bytes=20 * MB,
                                     mover="fifo",
                                     enable_initial_placement=False),
                       cf=CF)
    rt.alloc("a", size_bytes=10 * MB)
    rt.alloc("b", size_bytes=10 * MB)
    rt.start_loop(["p0", "p1"])
    _drive_loop(rt, [0.1, 0.05], [{"a": 1e6}, {"b": 5e5}])
    assert rt.plan is not None
    stale_plan = rt.plan
    assert rt.monitor._baseline            # baselines recorded

    rt.start_loop(["q0"])                  # second loop: new phase anatomy
    assert rt.plan is None                 # stale plan dropped
    assert rt.monitor._baseline == {}      # drift baselines reset
    assert rt.profiler.profile(0, "a") is None   # profiles reset
    assert rt.profiler.profile(1, "b") is None

    # the second loop profiles from scratch and plans on its own anatomy
    _drive_loop(rt, [0.2], [{"b": 2e6}])
    assert rt.plan is not None
    assert rt.plan is not stale_plan
    assert len(rt.plan.residents) == 1     # one-phase loop, not two


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------
def test_backend_registry_contents():
    names = available_backends()
    for expected in ("sim", "jax", "jax_async", "cpu_pool"):
        assert expected in names


def test_unknown_backend_raises_with_listing():
    with pytest.raises(ValueError, match="sim"):
        make_backend("cuda_streams", MACHINE)


def test_backend_reregistration_guard():
    with pytest.raises(ValueError, match="already registered"):
        register_backend("jax", lambda machine, **_: None)
    sentinel = object()
    register_backend("test_backend_tmp", lambda machine, **_: sentinel,
                     overwrite=True)
    assert make_backend("test_backend_tmp", MACHINE) is sentinel


def test_config_backend_string_resolves():
    assert isinstance(
        Session(MACHINE, RuntimeConfig(backend="jax")).backend,
        JaxTierBackend)
    assert isinstance(
        Session(MACHINE, RuntimeConfig(backend="jax_async")).backend,
        AsyncJaxTierBackend)
    sim = Session(MACHINE, RuntimeConfig(backend="sim", mover="slack"))
    assert isinstance(sim.backend, ChannelSimBackend)
    fifo = Session(MACHINE, RuntimeConfig(backend="sim", mover="fifo"))
    assert isinstance(fifo.backend, SimTierBackend)


def test_async_jax_backend_lands_on_settle_or_wait():
    import jax.numpy as jnp
    reg = ObjectRegistry()
    b = AsyncJaxTierBackend(MACHINE)
    obj = reg.alloc("x", 1024, payload=jnp.ones((256,), jnp.float32))
    h = b.start_move(obj, "fast")
    assert h is not None
    # wait fences per leaf and flips the tier
    assert b.wait(h) == 0.0
    assert obj.tier == "fast"
    # settle after landing is a no-op
    b.settle(0.0)
    assert obj.tier == "fast"
    # logical (payload-free) objects flip immediately
    o2 = reg.alloc("y", 1024)
    assert b.start_move(o2, "fast") is None
    assert o2.tier == "fast"


def test_async_jax_backend_prunes_handles_on_wait():
    """wait()/complete() must drop the landed handle (and its leaf refs)
    even when the caller never settles — the FIFO mover's pattern
    (regression: unbounded _open growth pinning moved buffers)."""
    import jax.numpy as jnp
    reg = ObjectRegistry()
    b = AsyncJaxTierBackend(MACHINE)
    for i in range(4):
        obj = reg.alloc(f"o{i}", 256, payload=jnp.ones((64,), jnp.float32))
        b.wait(b.start_move(obj, "fast"))
    assert b._open == []


def test_async_jax_backend_chains_after_eviction():
    """A fetch chained after an eviction must not dispatch until the
    eviction landed (capacity ordering: no transient double-residency)."""
    import jax.numpy as jnp
    reg = ObjectRegistry()
    b = AsyncJaxTierBackend(MACHINE)
    victim = reg.alloc("victim", 256,
                       payload=jnp.ones((64,), jnp.float32), tier="fast")
    ev = b.start_move(victim, "slow")
    incoming = reg.alloc("incoming", 256,
                         payload=jnp.ones((64,), jnp.float32))
    b.start_move(incoming, "fast", after=ev)
    assert ev.landed and victim.tier == "slow"   # space freed first


def test_phase_overrides_are_per_field():
    """Explicit accesses must not discard the source's virtual elapsed or
    its access_bins (regression: all-or-nothing source bypass)."""
    rt = _session()
    rt.register("x", 8 * MB)
    src = ManualSource()
    src.set("p0", accesses={"x": 1e5}, elapsed=0.25,
            access_bins={"x": [3.0, 1.0]})
    rt.attach_source(src)
    with rt.iteration():
        with rt.phase("p0", accesses={"x": 7e5}) as pc:
            pass
    assert pc.elapsed == 0.25                    # source virtual time kept
    prof = rt.profiler.profile(0, "x")
    assert prof is not None and prof.phase_time == 0.25
    assert prof.bin_counts is not None           # source bins still flowed


def test_async_jax_backend_is_done_probe():
    """is_done must report completion without blocking (the slack mover's
    eviction path probes it so in-flight evictions stay off the fence)."""
    import jax.numpy as jnp
    reg = ObjectRegistry()
    b = AsyncJaxTierBackend(MACHINE)
    assert b.is_done(None)
    obj = reg.alloc("x", 256, payload=jnp.ones((64,), jnp.float32),
                    tier="fast")
    h = b.start_move(obj, "slow")
    for leaf in h.leaves:
        leaf.block_until_ready()
    assert b.is_done(h)                  # ready leaves: done, not landed
    b.settle(0.0)
    assert h.landed and b.is_done(h)


def test_async_jax_backend_settle_lands_ready_copies():
    import jax.numpy as jnp
    reg = ObjectRegistry()
    b = AsyncJaxTierBackend(MACHINE)
    obj = reg.alloc("x", 1024, payload={"w": jnp.ones((64,), jnp.float32)})
    h = b.start_move(obj, "fast")
    for leaf in h.leaves:                   # force readiness, then settle
        leaf.block_until_ready()
    b.settle(0.0)
    assert obj.tier == "fast" and h.landed


def test_cpu_pool_backend_registered_and_configurable():
    b = make_backend("cpu_pool", MACHINE, pool_workers=3)
    assert isinstance(b, CpuPoolBackend)
    rt = Session(MACHINE, RuntimeConfig(backend="cpu_pool"))
    assert isinstance(rt.backend, CpuPoolBackend)
    b.shutdown()
    rt.backend.shutdown()


def test_cpu_pool_backend_moves_and_lands_on_settle():
    """The memcpy pool copies numpy leaves on workers; the tier (and the
    relocated payload) flips only when the finished copy is settled or
    fenced — the same in-flight semantics as the async jax backend."""
    import numpy as np
    reg = ObjectRegistry()
    b = CpuPoolBackend(MACHINE, workers=2)
    try:
        src = np.arange(4096, dtype=np.float32)
        obj = reg.alloc("x", src.nbytes, payload={"w": src})
        h = b.start_move(obj, "fast")
        assert h is not None
        h.future.result()               # copy finished on the worker...
        assert obj.tier == "slow"       # ...but not yet landed
        b.settle(0.0)
        assert obj.tier == "fast" and h.landed
        moved = obj.payload["w"]
        assert moved is not src and np.array_equal(moved, src)
        # wait() fences and lands; logical objects flip immediately
        o2 = reg.alloc("y", 1024, payload={"w": np.ones(256, np.float32)})
        assert b.wait(b.start_move(o2, "fast")) == 0.0
        assert o2.tier == "fast"
        o3 = reg.alloc("z", 1024)
        assert b.start_move(o3, "fast") is None and o3.tier == "fast"
        assert b._open == []            # landed handles pruned
    finally:
        b.shutdown()


def test_cpu_pool_backend_chains_after_eviction():
    """start_move(after=) orders a fetch behind the eviction freeing its
    space: the fetch's worker blocks on the eviction's copy, the caller
    never does, and is_done stays a non-blocking probe."""
    import numpy as np
    reg = ObjectRegistry()
    b = CpuPoolBackend(MACHINE, workers=1)      # one worker: strict order
    try:
        victim = reg.alloc("victim", 4096,
                           payload={"w": np.zeros(1024, np.float32)},
                           tier="fast")
        incoming = reg.alloc("incoming", 4096,
                             payload={"w": np.ones(1024, np.float32)})
        ev = b.start_move(victim, "slow")
        h = b.start_move(incoming, "fast", after=ev)
        assert b.is_done(None)
        b.complete(h)                   # fencing the fetch lands it
        assert incoming.tier == "fast"
        assert ev.future.done()         # predecessor necessarily finished
        b.settle(0.0)
        assert victim.tier == "slow"
    finally:
        b.shutdown()


def test_cpu_pool_backend_through_runtime_end_to_end():
    """A session on backend='cpu_pool' plans and migrates numpy-payload
    objects through the slack mover's settle/fence path."""
    import numpy as np
    rt = UnimemRuntime(MACHINE,
                       RuntimeConfig(fast_capacity_bytes=3 * MB // 2,
                                     backend="cpu_pool",
                                     enable_partitioning=False), cf=CF)
    hot = rt.register("hot", size_bytes=MB,
                      payload={"w": np.ones(MB // 4, np.float32)})
    cold = rt.register("cold", size_bytes=MB,
                       payload={"w": np.ones(MB // 4, np.float32)})
    for _ in range(4):
        with rt.iteration():
            with rt.phase("compute", accesses={"hot": 1e6}, elapsed=0.05):
                pass
            with rt.phase("update", accesses={"cold": 1e3}, elapsed=0.01):
                pass
    assert rt.plan is not None
    assert hot.tier == "fast"
    assert cold.tier == "slow"
    rt.backend.shutdown()


def test_async_backend_through_runtime_end_to_end():
    """A session on backend='jax_async' plans and moves real arrays; the
    slack mover's settle path lands tiers without explicit waits."""
    import jax.numpy as jnp
    rt = UnimemRuntime(MACHINE,
                       RuntimeConfig(fast_capacity_bytes=3 * MB // 2,
                                     backend="jax_async",
                                     enable_partitioning=False), cf=CF)
    hot = rt.register("hot", jnp.ones((256 * 1024,), jnp.float32))
    cold = rt.register("cold", jnp.ones((256 * 1024,), jnp.float32))
    for _ in range(4):
        with rt.iteration():
            with rt.phase("compute", accesses={"hot": 1e6}, elapsed=0.05):
                pass
            with rt.phase("update", accesses={"cold": 1e3}, elapsed=0.01):
                pass
    assert rt.plan is not None
    assert hot.tier == "fast"
    assert cold.tier == "slow"
