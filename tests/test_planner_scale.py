"""Serving-tick planner properties at scale: scoped replans bit-identical
to full rebuilds under randomized drift, dominance-bound (prune)
soundness, whole-decision global reuse, array-knapsack oracle parity
(numpy and forced-jax paths), entry-residency reconciliation, and
round-trips of the benefit/class decision caches."""

import random

import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # no hypothesis: seeded shim
    from _propcheck import st, given, settings

from test_policy import M, MB, build_chunk_fixture, plans_equal

from repro.core import CalibrationConstants, Planner, PlanProgram
from repro.core import knapsack
from repro.core.partition import resplit_refs
from repro.core.phase import PhaseTraceEvent


def _drift(reg, graph, prof, refs, times, phases, seed):
    """Shift the access *intensity* of ``phases`` (same reference sets,
    counts rescaled) and re-run the scoped attribution stages — the
    localized-drift tick the scoped replan path targets."""
    rng = random.Random(seed)
    prof.decay(0.25, phases=list(phases))
    for i in phases:
        prof.observe(PhaseTraceEvent(i, times[i], {
            k: v * rng.uniform(0.5, 2.0) for k, v in refs[i].items()}))
    prof.annotate_graph(graph)
    resplit_refs(graph, reg)


def _standing_plan(planner, graph, prof):
    local = planner.plan_local(graph, prof)
    glob = planner.plan_global(graph, prof)
    return local, glob


# ---------------------------------------------------------------------------
# scoped replan == full rebuild, randomized drift
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 1000))
@settings(max_examples=8, deadline=None)
def test_scoped_replan_bitidentical_under_random_drift(seed):
    """Property: after drifting a random subset of phases, the scoped
    replan (standing decisions + standing global rows) and a cold
    from-scratch rebuild produce the same plan — moves, residents,
    predicted time AND best-of-two winner."""
    rng = random.Random(seed ^ 0xD51F7)
    cap = rng.choice([64, 128, 256]) * MB
    reg, graph, prof, refs, times = build_chunk_fixture(
        300, seed=seed % 3)
    planner = Planner(M, reg, CalibrationConstants(), cap)
    local, glob = _standing_plan(planner, graph, prof)
    k = rng.choice([1, 1, 2, 3])
    phases = sorted(rng.sample(range(len(graph)), k))
    _drift(reg, graph, prof, refs, times, phases, seed)
    scoped = planner.plan(graph, prof,
                          standing=local.phase_decisions,
                          standing_global=glob.global_contribs,
                          standing_digest=local.graph_digest)
    full = Planner(M, reg, CalibrationConstants(), cap).plan(graph, prof)
    assert plans_equal(scoped, full)


def test_scoped_single_phase_drift_reuses_and_matches():
    """The serving-tick shape: one drifted phase out of 16 — everything
    else must be recognized as unchanged (local decisions and global
    rows both), and the plan must equal a cold rebuild's exactly."""
    n_phases = 16
    reg, graph, prof, refs, times = build_chunk_fixture(
        400, n_phases=n_phases)
    planner = Planner(M, reg, CalibrationConstants(), 128 * MB)
    local, glob = _standing_plan(planner, graph, prof)
    _drift(reg, graph, prof, refs, times, [n_phases - 1], seed=1)
    scoped = planner.plan(graph, prof,
                          standing=local.phase_decisions,
                          standing_global=glob.global_contribs,
                          standing_digest=local.graph_digest)
    full = Planner(M, reg, CalibrationConstants(), 128 * MB).plan(
        graph, prof)
    assert plans_equal(scoped, full)
    # every undrifted global row came from the standing contribs
    assert scoped.global_rows_reused >= n_phases - 1
    sl = planner.plan_local(graph, prof, standing=local.phase_decisions,
                            standing_digest=local.graph_digest)
    assert sum(1 for d in sl.phase_decisions if d.reused) >= n_phases - 1


# ---------------------------------------------------------------------------
# dominance bound + whole-decision reuse
# ---------------------------------------------------------------------------
def test_dominance_bound_prunes_soundly():
    """When the chooser's bound proves the global solve cannot win, the
    solve is skipped — and an independent, unpruned global solve indeed
    loses the best-of-two, so the pruned and unpruned choosers agree."""
    cap = 64 * MB
    reg, graph, prof, _, _ = build_chunk_fixture(300)
    planner = Planner(M, reg, CalibrationConstants(), cap)
    plan = planner.plan(graph, prof)
    assert plan.global_mode == "pruned"     # this fixture trips the bound
    assert plan.strategy == "local"
    fresh = Planner(M, reg, CalibrationConstants(), cap)
    local = fresh.plan_local(graph, prof)
    glob = fresh.plan_global(graph, prof)
    assert glob.global_mode == "solved"
    # the skipped solve could not have beaten local (ties go to local)
    assert glob.predicted_iteration_time >= local.predicted_iteration_time
    assert plans_equal(plan, local)


def test_unchanged_rebuild_reuses_whole_global_decision():
    """Zero drift: a second plan() on the same planner must hit the
    whole-decision memo (no re-solve) and return the identical plan."""
    reg, graph, prof, _, _ = build_chunk_fixture(300)
    planner = Planner(M, reg, CalibrationConstants(), 256 * MB)
    first = planner.plan(graph, prof)
    local = planner.plan_local(graph, prof)
    second = planner.plan(graph, prof)
    assert plans_equal(first, second)
    assert second.global_mode == "reused"
    sl = planner.plan_local(graph, prof, standing=local.phase_decisions,
                            standing_digest=local.graph_digest)
    assert all(d.reused for d in sl.phase_decisions)


# ---------------------------------------------------------------------------
# array knapsack == reference oracle
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 400))
@settings(max_examples=40, deadline=None)
def test_solve_arrays_matches_reference(seed):
    """The array entry point (values/sizes vectors, index output) returns
    exactly the reference solver's selection — negatives, zero-capacity
    and oversized items included."""
    rng = random.Random(seed)
    n = rng.randint(0, 60)
    its = [knapsack.Item(f"o{i}", rng.uniform(-2.0, 4.0),
                         rng.randint(1, 48 * MB)) for i in range(n)]
    cap = rng.randint(0, 256) * MB
    idx = knapsack.solve_arrays(
        np.array([it.value for it in its], dtype=np.float64),
        np.array([it.size_bytes for it in its], dtype=np.int64), cap)
    assert [its[i].name for i in idx] == knapsack.solve_reference(its, cap)


def test_solve_arrays_jax_path_matches_reference():
    """Force the jitted lax.scan DP (off by default on CPU) above its
    work threshold and require the bit-packed keep rows to reproduce the
    reference selection exactly."""
    pytest.importorskip("jax")
    rng = random.Random(7)
    its = [knapsack.Item(f"o{i}", rng.uniform(-0.5, 2.0),
                         rng.randint(1, 4) * MB) for i in range(600)]
    cap = 256 * MB      # n * qcap ~ 9.8M cells: above _JAX_MIN_WORK
    values = np.array([it.value for it in its], dtype=np.float64)
    sizes = np.array([it.size_bytes for it in its], dtype=np.int64)
    old = knapsack.use_jax
    knapsack.use_jax = True
    try:
        idx = knapsack.solve_arrays(values, sizes, cap)
    finally:
        knapsack.use_jax = old
    assert [its[i].name for i in idx] == knapsack.solve_reference(its, cap)


# ---------------------------------------------------------------------------
# entry-residency reconciliation
# ---------------------------------------------------------------------------
def test_entry_shed_reconciles_overshoot():
    """An entry residency overshooting the budget (capacity shrank under
    a standing placement) is shed at phase 0: lowest-traffic unpinned
    residents demoted first, priced as evictions, identically on the
    vectorized and oracle paths."""
    cap = 64 * MB
    reg, graph, prof, _, _ = build_chunk_fixture(300)
    fast, total = [], 0
    for o in reg:
        if total >= 96 * MB:
            break
        o.tier = "fast"
        total += o.size_bytes
        fast.append(o)
    fast[0].pinned = True
    # mirror the shed rule: ascending (traffic, name), pinned skipped
    traffic = {o.name: sum(p.refs.get(o.name, 0.0) for p in graph)
               for o in fast}
    expected, left = [], total
    for o in sorted(fast, key=lambda o: (traffic[o.name], o.name)):
        if left <= cap:
            break
        if o.pinned:
            continue
        expected.append(o.name)
        left -= o.size_bytes
    assert expected, "fixture must actually overshoot"
    plans = {}
    for vec in (True, False):
        plan = Planner(M, reg, CalibrationConstants(), cap,
                       vectorized=vec).plan_local(graph, prof)
        shed = plan.moves[:len(expected)]
        assert [m.obj for m in shed] == expected
        assert all(m.dst == "slow" and m.needed_by == 0 for m in shed)
        assert all(m.est_unhidden_cost > 0.0 for m in shed)
        assert fast[0].name not in {m.obj for m in plan.moves
                                    if m.dst == "slow"}
        plans[vec] = plan
    assert plans_equal(plans[True], plans[False])


# ---------------------------------------------------------------------------
# decision-cache round-trip
# ---------------------------------------------------------------------------
def test_roundtrip_preserves_benefit_classes_and_cls_rows():
    """The gain-class caches ride the IR: phase decisions keep their
    per-object class maps and global rows their packed class vectors
    through JSON, and a replan from the deserialized standing state is
    still bit-identical with full reuse."""
    reg, graph, prof, _, _ = build_chunk_fixture(200)
    planner = Planner(M, reg, CalibrationConstants(), 256 * MB)
    local, glob = _standing_plan(planner, graph, prof)
    prog = PlanProgram.from_plan(
        local, policy="unimem", provenance=[], profile_epoch=prof.epoch,
        chunk_generation=reg.generation, capacity_bytes=256 * MB,
        phase_decisions=local.phase_decisions,
        global_contribs=glob.global_contribs,
        graph_digest=local.graph_digest)
    back = PlanProgram.from_json(prog.to_json())
    assert any(d.classes for d in prog.phase_decisions)
    for a, b in zip(back.phase_decisions, prog.phase_decisions):
        assert a.classes == b.classes
    assert any(g.cls_row is not None for g in prog.global_contribs)
    for a, b in zip(back.global_contribs, prog.global_contribs):
        if b.cls_row is None:
            assert a.cls_row is None
        else:
            assert np.array_equal(a.cls_row, b.cls_row)
            assert a.cls_row.dtype == b.cls_row.dtype
    replan = planner.plan(graph, prof,
                          standing=back.phase_decisions,
                          standing_global=back.global_contribs,
                          standing_digest=back.graph_digest)
    full = Planner(M, reg, CalibrationConstants(), 256 * MB).plan(
        graph, prof)
    assert plans_equal(replan, full)
