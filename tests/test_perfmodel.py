"""Unit + property tests for the paper's Eq. (1)-(5) performance models."""

import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # no hypothesis: seeded shim
    from _propcheck import st, given, settings

from repro.core import (CalibrationConstants, PAPER_DRAM_NVM, Sensitivity,
                        benefit, calibrate, classify, consumed_bandwidth,
                        movement_cost, weight)
from repro.core.perfmodel import benefit_bw, benefit_lat
from repro.core.profiler import ObjectPhaseProfile

M = PAPER_DRAM_NVM
CF = CalibrationConstants()


def prof(data_access=1e6, n_samples=1e5, with_access=1e4, time=0.1):
    return ObjectPhaseProfile(0, "o", data_access, n_samples, with_access,
                              time)


def test_eq1_matches_paper_example():
    # paper: 10s phase, 1 GHz CPU, sample every 1000 cycles -> 1e7 samples;
    # 1e5 samples with accesses -> the object is "active" for 0.1s
    p = ObjectPhaseProfile(0, "o", data_access=1e6, n_samples=1e7,
                           samples_with_access=1e5, phase_time=10.0)
    bw = consumed_bandwidth(p, M)
    assert bw == pytest.approx(1e6 * M.cacheline_bytes / 0.1)


def test_classification_thresholds():
    peak = M.bw_peak
    # consumed bw >= 80% of peak -> bandwidth sensitive
    t = 1.0
    acc_high = 0.9 * peak * t / M.cacheline_bytes
    p = ObjectPhaseProfile(0, "o", acc_high, 1e6, 1e6, t)
    assert classify(p, M) is Sensitivity.BANDWIDTH
    acc_low = 0.05 * peak * t / M.cacheline_bytes
    p = ObjectPhaseProfile(0, "o", acc_low, 1e6, 1e6, t)
    assert classify(p, M) is Sensitivity.LATENCY
    acc_mid = 0.5 * peak * t / M.cacheline_bytes
    p = ObjectPhaseProfile(0, "o", acc_mid, 1e6, 1e6, t)
    assert classify(p, M) is Sensitivity.MIXED


@given(acc=st.floats(1.0, 1e9))
@settings(max_examples=50, deadline=None)
def test_eq2_eq3_benefits_positive(acc):
    """Moving slow->fast can never predict negative benefit (fast tier is
    faster on both axes in every profile)."""
    p = prof(data_access=acc)
    assert benefit_bw(p, M, CF) >= 0.0
    assert benefit_lat(p, M, CF) >= 0.0
    assert benefit(p, M, CF) >= 0.0


@given(size=st.integers(1, 10 ** 10), overlap=st.floats(0.0, 10.0))
@settings(max_examples=100, deadline=None)
def test_eq4_cost_nonnegative_and_overlap_monotone(size, overlap):
    c0 = movement_cost(size, M, 0.0)
    c = movement_cost(size, M, overlap)
    assert c >= 0.0
    assert c <= c0                      # overlap can only reduce cost
    if overlap >= size / M.copy_bw:
        assert c == 0.0                 # fully hidden


def test_eq5_weight():
    assert weight(1.0, 0.3, 0.2) == pytest.approx(0.5)


def test_mixed_takes_max():
    p = prof()
    b = benefit(p, M, CF, Sensitivity.MIXED)
    assert b == pytest.approx(max(benefit_bw(p, M, CF),
                                  benefit_lat(p, M, CF)))


def test_calibration_positive_and_finite():
    cf = calibrate(M)
    assert 0.1 < cf.cf_bw < 10.0
    assert 0.1 < cf.cf_lat < 10.0
