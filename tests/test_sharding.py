"""Sharding rule tests — pure spec logic over an AbstractMesh (no devices)."""

import jax
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.distributed import sharding as shd


def make_abstract_mesh(shape, names):
    """AbstractMesh across JAX versions: <=0.4.x takes one
    ``((name, size), ...)`` shape tuple; >=0.5 takes ``(sizes, names)``."""
    if jax.__version_info__ >= (0, 5, 0):
        return AbstractMesh(tuple(shape), tuple(names))
    return AbstractMesh(tuple(zip(names, shape)))


MESH = make_abstract_mesh((16, 16), ("data", "model"))
MESH3 = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def spec_eq(a, b):
    """PartitionSpec equality across JAX versions: newer JAX canonicalizes
    1-tuples (``('data',)``) to bare names (``'data'``); older versions
    compare entries strictly."""
    def canon(spec):
        return tuple(e[0] if isinstance(e, tuple) and len(e) == 1 else e
                     for e in spec)
    return canon(a) == canon(b)


def test_fit_drops_nondivisible_axes():
    # 8 heads cannot shard 16 ways -> dropped
    assert spec_eq(shd.fit(MESH, (8, 128), "model", None), P(None, None))
    assert spec_eq(shd.fit(MESH, (32, 128), "model", None), P("model", None))


def test_fit_keeps_divisible_prefix():
    # ("pod","data") over dim 4: pod(2) divides, pod*data(32) does not
    spec = shd.fit(MESH3, (4, 64), ("pod", "data"), None)
    assert spec_eq(spec, P("pod", None))


def test_param_specs_rules():
    pshapes = {
        "embed": jax.ShapeDtypeStruct((64000, 4096), jax.numpy.bfloat16),
        "head": jax.ShapeDtypeStruct((4096, 64000), jax.numpy.bfloat16),
        "blocks": {
            "attn": {"wq": jax.ShapeDtypeStruct((32, 4096, 4096),
                                                jax.numpy.bfloat16)},
            "mlp": {"w_down": jax.ShapeDtypeStruct((32, 11008, 4096),
                                                   jax.numpy.bfloat16)},
        },
    }
    specs = shd.param_specs(MESH, pshapes)
    assert spec_eq(specs["embed"], P(None, "model"))          # untied: d-sharded
    assert spec_eq(specs["head"], P(None, "model"))
    assert spec_eq(specs["blocks"]["attn"]["wq"], P(None, ("data",), "model"))
    assert spec_eq(specs["blocks"]["mlp"]["w_down"], P(None, "model", ("data",)))


def test_tied_embed_vocab_sharded():
    pshapes = {"embed": jax.ShapeDtypeStruct((256000, 2048),
                                             jax.numpy.bfloat16)}
    specs = shd.param_specs(MESH, pshapes, tied=True)
    assert spec_eq(specs["embed"], P("model", None))


def test_cache_specs_kv_head_fallback_to_sequence():
    cache = {"k": jax.ShapeDtypeStruct((28, 128, 32768, 2, 128),
                                       jax.numpy.bfloat16),
             "v": jax.ShapeDtypeStruct((28, 128, 32768, 2, 128),
                                       jax.numpy.bfloat16)}
    specs = shd.cache_specs(MESH, None, cache, batch=128)
    # kv=2 cannot split 16 ways -> sequence sharded over "model" (SP)
    assert spec_eq(specs["k"], P(None, ("data",), "model", None, None))


def test_cache_specs_kv_heads_when_divisible():
    cache = {"k": jax.ShapeDtypeStruct((32, 128, 32768, 32, 128),
                                       jax.numpy.bfloat16)}
    specs = shd.cache_specs(MESH, None, cache, batch=128)
    assert spec_eq(specs["k"], P(None, ("data",), None, "model", None))


def test_cache_specs_sp_when_batch_too_small():
    cache = {"k": jax.ShapeDtypeStruct((7, 1, 524288, 32, 64),
                                       jax.numpy.bfloat16)}
    specs = shd.cache_specs(MESH, None, cache, batch=1)
    # batch=1: shard the 500k sequence over "data" + heads over "model"
    assert spec_eq(specs["k"], P(None, None, "data", "model", None))


def test_opt_specs_mirror_params():
    pshapes = {"w": jax.ShapeDtypeStruct((4096, 4096), jax.numpy.bfloat16)}
    pspecs = shd.param_specs(MESH, pshapes)
    oshapes = {"mu": {"w": jax.ShapeDtypeStruct((4096, 4096),
                                                jax.numpy.float32)},
               "step": jax.ShapeDtypeStruct((), jax.numpy.int32)}
    ospecs = shd.opt_specs(MESH, oshapes, pshapes, pspecs)
    assert ospecs["mu"]["w"] == pspecs["w"]
    assert spec_eq(ospecs["step"], P())
